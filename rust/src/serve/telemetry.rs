//! Live per-job telemetry: a bounded [`FeedbackRing`] per job plus a
//! long-poll wait, behind `GET /jobs/<id>/feedback?since=<seq>`.
//!
//! Publishers are the worker pool's per-job monitors (heartbeat samples
//! while a scenario runs — scenarios are black boxes to the service, so
//! the heartbeat reports elapsed wall clock at a fixed cadence rather
//! than inventing per-step numbers the runner never exposed). The ring
//! keeps only recent samples; [`FeedbackRing::snapshot_since`]'s
//! monotonic cursors mean a poller never re-copies what it has seen and
//! a slow poller loses old samples silently instead of blocking the
//! publisher.

use crate::obs::detect::{Detection, DetectionKind, DetectorConfig, SeriesDetector};
use crate::obs::SpanRecord;
use crate::tune::{FeedbackRing, StepFeedback};
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Samples retained per job.
const RING_CAP: usize = 256;
/// Detections retained per job.
const DETECTIONS_CAP: usize = 64;
/// Span records retained per job (for `GET /jobs/<id>/trace`).
const SPANS_CAP: usize = 50_000;

/// One job's live feed.
pub struct JobFeed {
    ring: Mutex<FeedbackRing>,
    /// Signaled on every publish and on close.
    changed: Condvar,
    closed: Mutex<bool>,
    /// Online watcher over the published `busbw_gbps` stream (zero
    /// samples — heartbeats — are skipped; they carry no bandwidth).
    watch: Mutex<WatchState>,
    /// Span snapshot captured around the job's run, for the trace route.
    spans: Mutex<Vec<SpanRecord>>,
}

struct WatchState {
    busbw: SeriesDetector,
    detections: Vec<Detection>,
}

impl JobFeed {
    fn new() -> JobFeed {
        JobFeed {
            ring: Mutex::new(FeedbackRing::new(RING_CAP)),
            changed: Condvar::new(),
            closed: Mutex::new(false),
            watch: Mutex::new(WatchState {
                busbw: SeriesDetector::new(DetectorConfig::throughput()),
                detections: Vec::new(),
            }),
            spans: Mutex::new(Vec::new()),
        }
    }

    /// Append one sample and wake pollers. Non-heartbeat samples (those
    /// carrying a bandwidth figure) also flow through the job's online
    /// throughput detector, so a regression is stamped into the feed
    /// while the job still runs.
    pub fn publish(&self, fb: StepFeedback) {
        if fb.busbw_gbps > 0.0 {
            let mut watch = self.watch.lock().unwrap();
            if let Some((z, baseline)) = watch.busbw.observe(fb.busbw_gbps) {
                if watch.detections.len() < DETECTIONS_CAP {
                    watch.detections.push(Detection {
                        kind: DetectionKind::ThroughputRegression,
                        series: "busbw_gbps".to_string(),
                        at: fb.step,
                        z,
                        baseline,
                        value: fb.busbw_gbps,
                    });
                }
            }
        }
        self.ring.lock().unwrap().push(fb);
        self.changed.notify_all();
    }

    /// Detections the online watcher has stamped so far.
    pub fn detections(&self) -> Vec<Detection> {
        self.watch.lock().unwrap().detections.clone()
    }

    /// Attach the span snapshot captured around this job's run (bounded
    /// at [`SPANS_CAP`]; overflow keeps the newest records).
    pub fn set_spans(&self, mut spans: Vec<SpanRecord>) {
        if spans.len() > SPANS_CAP {
            spans.drain(..spans.len() - SPANS_CAP);
        }
        *self.spans.lock().unwrap() = spans;
    }

    /// The stored span snapshot (empty when the job ran untraced).
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.spans.lock().unwrap().clone()
    }

    /// Mark the feed finished (job left the running state) and wake
    /// pollers so they can observe `done`.
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.changed.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        *self.closed.lock().unwrap()
    }

    /// Long-poll: samples with sequence `>= since` (oldest → newest),
    /// the next cursor, and whether the feed is finished. Blocks up to
    /// `timeout` waiting for news when the delta would be empty.
    pub fn poll_since(
        &self,
        since: u64,
        timeout: Duration,
    ) -> (Vec<StepFeedback>, u64, bool) {
        let deadline = Instant::now() + timeout;
        // The wait is keyed on the closed flag's mutex so close() can
        // wake us; the ring has its own shorter-held lock.
        let mut closed = self.closed.lock().unwrap();
        loop {
            let (samples, next) = self.ring.lock().unwrap().snapshot_since(since);
            if !samples.is_empty() || *closed {
                return (samples, next, *closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return (samples, next, *closed);
            }
            let (guard, _timeout_result) =
                self.changed.wait_timeout(closed, deadline - now).unwrap();
            closed = guard;
        }
    }
}

/// The registry of live feeds, keyed by job id. Feeds for finished jobs
/// stay until [`TelemetryHub::remove`] (the daemon keeps them so late
/// watchers still see the tail + `done`).
#[derive(Default)]
pub struct TelemetryHub {
    feeds: Mutex<BTreeMap<u64, Arc<JobFeed>>>,
}

impl TelemetryHub {
    pub fn new() -> TelemetryHub {
        TelemetryHub::default()
    }

    /// Create (or return) the feed for `job_id`.
    pub fn feed(&self, job_id: u64) -> Arc<JobFeed> {
        Arc::clone(
            self.feeds
                .lock()
                .unwrap()
                .entry(job_id)
                .or_insert_with(|| Arc::new(JobFeed::new())),
        )
    }

    /// The feed for `job_id` if one was ever created.
    pub fn get(&self, job_id: u64) -> Option<Arc<JobFeed>> {
        self.feeds.lock().unwrap().get(&job_id).cloned()
    }

    pub fn remove(&self, job_id: u64) {
        self.feeds.lock().unwrap().remove(&job_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(step: u64, wall: f64) -> StepFeedback {
        StepFeedback { step, wall_s: wall, compute_s: 0.0, comm_busy_s: 0.0, busbw_gbps: 0.0 }
    }

    #[test]
    fn poll_returns_immediately_when_samples_exist() {
        let hub = TelemetryHub::new();
        let feed = hub.feed(1);
        feed.publish(fb(0, 0.1));
        feed.publish(fb(1, 0.2));
        let (samples, next, done) = feed.poll_since(0, Duration::from_secs(5));
        assert_eq!(samples.len(), 2);
        assert_eq!(next, 2);
        assert!(!done);
        // Cursor resume: nothing new → times out empty, quickly.
        let t0 = Instant::now();
        let (samples, next, _) = feed.poll_since(next, Duration::from_millis(30));
        assert!(samples.is_empty());
        assert_eq!(next, 2);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn poll_wakes_on_publish_from_another_thread() {
        let hub = Arc::new(TelemetryHub::new());
        let feed = hub.feed(7);
        let publisher = {
            let feed = Arc::clone(&feed);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(40));
                feed.publish(fb(0, 0.5));
            })
        };
        let (samples, next, done) = feed.poll_since(0, Duration::from_secs(5));
        publisher.join().unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(next, 1);
        assert!(!done);
    }

    #[test]
    fn sustained_busbw_collapse_is_stamped_into_the_feed() {
        let feed = TelemetryHub::new().feed(9);
        let sample = |step: u64, bw: f64| StepFeedback {
            step,
            wall_s: 0.1,
            compute_s: 0.05,
            comm_busy_s: 0.05,
            busbw_gbps: bw,
        };
        for step in 0..8 {
            feed.publish(sample(step, 10.0));
        }
        // Heartbeats (no bandwidth) must not poison the watcher.
        feed.publish(StepFeedback {
            step: 8,
            wall_s: 0.8,
            compute_s: 0.0,
            comm_busy_s: 0.0,
            busbw_gbps: 0.0,
        });
        assert!(feed.detections().is_empty(), "steady stream must stay silent");
        for step in 9..12 {
            feed.publish(sample(step, 0.5));
        }
        let dets = feed.detections();
        assert_eq!(dets.len(), 1, "{dets:?}");
        assert_eq!(dets[0].series, "busbw_gbps");
        assert!(dets[0].at >= 9);
    }

    #[test]
    fn span_snapshots_round_trip_and_stay_bounded() {
        let feed = TelemetryHub::new().feed(11);
        assert!(feed.spans().is_empty());
        let span = |seq: u64| crate::obs::SpanRecord {
            seq,
            rank: 0,
            step: seq as u32,
            start_us: seq * 10,
            dur_us: 5,
            bytes: 0,
            name: "compute".to_string(),
        };
        feed.set_spans((0..3).map(span).collect());
        assert_eq!(feed.spans().len(), 3);
        // Oversized snapshots keep the newest records.
        feed.set_spans((0..(super::SPANS_CAP as u64 + 10)).map(span).collect());
        let kept = feed.spans();
        assert_eq!(kept.len(), super::SPANS_CAP);
        assert_eq!(kept[0].seq, 10);
    }

    #[test]
    fn close_unblocks_pollers_with_done() {
        let feed = TelemetryHub::new().feed(3);
        let closer = {
            let feed = Arc::clone(&feed);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(40));
                feed.close();
            })
        };
        let (samples, _, done) = feed.poll_since(0, Duration::from_secs(5));
        closer.join().unwrap();
        assert!(samples.is_empty());
        assert!(done);
        assert!(feed.is_closed());
    }
}
