//! Live per-job telemetry: a bounded [`FeedbackRing`] per job plus a
//! long-poll wait, behind `GET /jobs/<id>/feedback?since=<seq>`.
//!
//! Publishers are the worker pool's per-job monitors (heartbeat samples
//! while a scenario runs — scenarios are black boxes to the service, so
//! the heartbeat reports elapsed wall clock at a fixed cadence rather
//! than inventing per-step numbers the runner never exposed). The ring
//! keeps only recent samples; [`FeedbackRing::snapshot_since`]'s
//! monotonic cursors mean a poller never re-copies what it has seen and
//! a slow poller loses old samples silently instead of blocking the
//! publisher.

use crate::tune::{FeedbackRing, StepFeedback};
use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Samples retained per job.
const RING_CAP: usize = 256;

/// One job's live feed.
pub struct JobFeed {
    ring: Mutex<FeedbackRing>,
    /// Signaled on every publish and on close.
    changed: Condvar,
    closed: Mutex<bool>,
}

impl JobFeed {
    fn new() -> JobFeed {
        JobFeed {
            ring: Mutex::new(FeedbackRing::new(RING_CAP)),
            changed: Condvar::new(),
            closed: Mutex::new(false),
        }
    }

    /// Append one sample and wake pollers.
    pub fn publish(&self, fb: StepFeedback) {
        self.ring.lock().unwrap().push(fb);
        self.changed.notify_all();
    }

    /// Mark the feed finished (job left the running state) and wake
    /// pollers so they can observe `done`.
    pub fn close(&self) {
        *self.closed.lock().unwrap() = true;
        self.changed.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        *self.closed.lock().unwrap()
    }

    /// Long-poll: samples with sequence `>= since` (oldest → newest),
    /// the next cursor, and whether the feed is finished. Blocks up to
    /// `timeout` waiting for news when the delta would be empty.
    pub fn poll_since(
        &self,
        since: u64,
        timeout: Duration,
    ) -> (Vec<StepFeedback>, u64, bool) {
        let deadline = Instant::now() + timeout;
        // The wait is keyed on the closed flag's mutex so close() can
        // wake us; the ring has its own shorter-held lock.
        let mut closed = self.closed.lock().unwrap();
        loop {
            let (samples, next) = self.ring.lock().unwrap().snapshot_since(since);
            if !samples.is_empty() || *closed {
                return (samples, next, *closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return (samples, next, *closed);
            }
            let (guard, _timeout_result) =
                self.changed.wait_timeout(closed, deadline - now).unwrap();
            closed = guard;
        }
    }
}

/// The registry of live feeds, keyed by job id. Feeds for finished jobs
/// stay until [`TelemetryHub::remove`] (the daemon keeps them so late
/// watchers still see the tail + `done`).
#[derive(Default)]
pub struct TelemetryHub {
    feeds: Mutex<BTreeMap<u64, Arc<JobFeed>>>,
}

impl TelemetryHub {
    pub fn new() -> TelemetryHub {
        TelemetryHub::default()
    }

    /// Create (or return) the feed for `job_id`.
    pub fn feed(&self, job_id: u64) -> Arc<JobFeed> {
        Arc::clone(
            self.feeds
                .lock()
                .unwrap()
                .entry(job_id)
                .or_insert_with(|| Arc::new(JobFeed::new())),
        )
    }

    /// The feed for `job_id` if one was ever created.
    pub fn get(&self, job_id: u64) -> Option<Arc<JobFeed>> {
        self.feeds.lock().unwrap().get(&job_id).cloned()
    }

    pub fn remove(&self, job_id: u64) {
        self.feeds.lock().unwrap().remove(&job_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(step: u64, wall: f64) -> StepFeedback {
        StepFeedback { step, wall_s: wall, compute_s: 0.0, comm_busy_s: 0.0, busbw_gbps: 0.0 }
    }

    #[test]
    fn poll_returns_immediately_when_samples_exist() {
        let hub = TelemetryHub::new();
        let feed = hub.feed(1);
        feed.publish(fb(0, 0.1));
        feed.publish(fb(1, 0.2));
        let (samples, next, done) = feed.poll_since(0, Duration::from_secs(5));
        assert_eq!(samples.len(), 2);
        assert_eq!(next, 2);
        assert!(!done);
        // Cursor resume: nothing new → times out empty, quickly.
        let t0 = Instant::now();
        let (samples, next, _) = feed.poll_since(next, Duration::from_millis(30));
        assert!(samples.is_empty());
        assert_eq!(next, 2);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn poll_wakes_on_publish_from_another_thread() {
        let hub = Arc::new(TelemetryHub::new());
        let feed = hub.feed(7);
        let publisher = {
            let feed = Arc::clone(&feed);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(40));
                feed.publish(fb(0, 0.5));
            })
        };
        let (samples, next, done) = feed.poll_since(0, Duration::from_secs(5));
        publisher.join().unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(next, 1);
        assert!(!done);
    }

    #[test]
    fn close_unblocks_pollers_with_done() {
        let feed = TelemetryHub::new().feed(3);
        let closer = {
            let feed = Arc::clone(&feed);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(40));
                feed.close();
            })
        };
        let (samples, _, done) = feed.poll_since(0, Duration::from_secs(5));
        closer.join().unwrap();
        assert!(samples.is_empty());
        assert!(done);
        assert!(feed.is_closed());
    }
}
