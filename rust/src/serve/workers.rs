//! The worker pool: W threads draining the [`JobQueue`] into the engine
//! via the job-queue adapter ([`crate::engine::jobqueue`]).
//!
//! Each worker owns its own [`ScenarioRegistry`] (registries hold boxed
//! runners; building one per thread is cheap and sidesteps sharing), and
//! each running job gets a heartbeat monitor thread feeding the
//! telemetry hub: scenarios are black boxes to the service, so the
//! monitor publishes elapsed-wall-clock samples at a fixed cadence — an
//! honest liveness signal on the same [`crate::tune::StepFeedback`]
//! type the tuner consumes — plus one final sample at completion.
//! Before running, a worker consults the store for a persisted tuner
//! checkpoint and injects warm-start overrides; after a run that tuned
//! knobs, it persists the refreshed checkpoint.

use super::state::ServeState;
use crate::engine::jobqueue::{self, JobRequest};
use crate::engine::ScenarioRegistry;
use crate::serve::job::JobState;
use crate::tune::{KnobPoint, StepFeedback, TunerCheckpoint};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Heartbeat cadence for the per-job telemetry monitor.
const MONITOR_PERIOD: Duration = Duration::from_millis(100);

pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` threads draining `state.queue` until it closes.
    pub fn start(workers: usize, state: Arc<ServeState>) -> WorkerPool {
        let handles = (0..workers)
            .map(|i| {
                let state = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_main(&state))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Wait for every worker to finish (the queue must be closed first,
    /// or this blocks forever).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_main(state: &ServeState) {
    let registry = ScenarioRegistry::builtin();
    while let Some(job_id) = state.queue.pop() {
        run_one(state, &registry, job_id);
    }
}

/// Execute one popped job end to end: claim → warm-start → run (with a
/// heartbeat monitor) → record + persist.
fn run_one(state: &ServeState, registry: &ScenarioRegistry, job_id: u64) {
    // Claim: Queued → Running. A record can be missing or cancelled if
    // the daemon raced a cancellation; skip silently.
    let Some(mut request) = state.claim_running(job_id) else {
        return;
    };

    let warm = warm_start(state, registry, &mut request);
    if warm {
        state.mark_warm_started(job_id);
    }

    let feed = state.telemetry.feed(job_id);
    let t0 = Instant::now();
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let feed = Arc::clone(&feed);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut tick = 0u64;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(MONITOR_PERIOD);
                feed.publish(heartbeat(tick, t0));
                tick += 1;
            }
        })
    };

    // Snapshot the span ring around the run so `GET /jobs/<id>/trace`
    // can serve whatever the scenario traced (empty when tracing was
    // off — the route still answers with a valid, empty trace).
    let span_cursor = crate::obs::span::cursor();
    let result = jobqueue::execute(registry, &request);
    feed.set_spans(crate::obs::span::since(span_cursor, None).0);

    stop.store(true, Ordering::Relaxed);
    let _ = monitor.join();
    feed.publish(heartbeat(u64::MAX, t0)); // final sample: total elapsed
    state.queue.observe_job_duration(t0.elapsed());

    match result {
        Ok(outcome) => {
            if let Some(spec) = &outcome.tuned_knobs {
                persist_tuner(state, &request.scenario, spec);
            }
            state.finish(job_id, JobState::Done, None, Some(outcome.to_json()));
        }
        Err(e) => {
            state.finish(job_id, JobState::Failed, Some(format!("{e:#}")), None);
        }
    }
    feed.close();
}

fn heartbeat(tick: u64, t0: Instant) -> StepFeedback {
    StepFeedback {
        step: tick,
        wall_s: t0.elapsed().as_secs_f64(),
        compute_s: 0.0,
        comm_busy_s: 0.0,
        busbw_gbps: 0.0,
    }
}

/// Inject warm-start overrides from the store's checkpoint, if the
/// scenario is eligible. Returns whether anything was injected.
fn warm_start(state: &ServeState, registry: &ScenarioRegistry, request: &mut JobRequest) -> bool {
    let Some(ck) = state.store.load_tuner(&request.scenario) else {
        return false;
    };
    let Ok(scenario) = registry.get(&request.scenario) else {
        return false;
    };
    let overrides = jobqueue::warm_start_overrides(scenario.schema(), request, &ck);
    if overrides.is_empty() {
        return false;
    }
    request.params.extend(overrides);
    true
}

/// Persist the run's chosen knobs as the scenario's new checkpoint.
fn persist_tuner(state: &ServeState, scenario: &str, spec: &str) {
    match KnobPoint::parse_spec(spec) {
        Ok(point) => {
            let ck = TunerCheckpoint::from_point(point);
            if let Err(e) = state.store.save_tuner(scenario, &ck) {
                eprintln!("serve: failed to persist tuner state for {scenario}: {e:#}");
            }
        }
        Err(e) => eprintln!("serve: unparseable tuned_knobs from {scenario}: {e:#}"),
    }
}
