//! Ablation studies over the design choices DESIGN.md calls out —
//! extensions beyond the paper's figures, answering "how sensitive are
//! the conclusions to the knobs?":
//!
//! * fusion-buffer size and timeout (Horovod's 64 MB / 5 ms defaults),
//! * all-reduce algorithm (ring vs tree vs parameter-server cost models),
//! * the bandwidth × compression interaction grid.

use super::{simulate, SimParams};
use crate::models::timing::backward_trace;
use crate::models::ModelId;
use crate::report::{Figure, Series};

/// Fusion-buffer size sweep: scaling factor vs buffer MB at fixed 5 ms
/// timeout (measured-mode, 100 Gbps, 8 servers).
pub fn ablate_fusion_size(model: ModelId) -> Figure {
    let mut fig = Figure::new(
        "ablate_fusion_size",
        format!("Scaling factor vs fusion buffer size ({}, measured-mode, 100 Gbps)", model.name()),
        "buffer MB",
        "scaling factor",
    );
    let trace = backward_trace(&model.profile());
    let mut s = Series::new(model.name());
    for mb in [1.0, 4.0, 16.0, 64.0, 256.0, 1024.0] {
        let mut p = SimParams::horovod_like(trace.clone(), 8, 8, 100.0);
        p.fusion.buffer_bytes = (mb * 1e6) as usize;
        s.push(mb, simulate(&p).scaling_factor);
    }
    fig.series.push(s);
    fig
}

/// Fusion timeout sweep at fixed 64 MB buffer.
pub fn ablate_fusion_timeout(model: ModelId) -> Figure {
    let mut fig = Figure::new(
        "ablate_fusion_timeout",
        format!("Scaling factor vs fusion timeout ({}, measured-mode, 100 Gbps)", model.name()),
        "timeout ms",
        "scaling factor",
    );
    let trace = backward_trace(&model.profile());
    let mut s = Series::new(model.name());
    for ms in [0.5, 1.0, 2.0, 5.0, 10.0, 25.0, 100.0] {
        let mut p = SimParams::horovod_like(trace.clone(), 8, 8, 100.0);
        p.fusion.timeout_s = ms * 1e-3;
        s.push(ms, simulate(&p).scaling_factor);
    }
    fig.series.push(s);
    fig
}

/// Analytic per-step communication time of the three collective
/// algorithms at a given scale — the reason all-reduce strategies moved
/// from PS to rings, rendered as a figure.
pub fn ablate_collective_cost(model: ModelId, bandwidth_gbps: f64) -> Figure {
    let mut fig = Figure::new(
        "ablate_collectives",
        format!("Analytic wire time per step ({}, {bandwidth_gbps} Gbps)", model.name()),
        "servers",
        "wire seconds at the bottleneck link",
    );
    let s_bytes = model.profile().total_bytes() as f64;
    let rate = crate::gbps_to_bytes_per_sec(bandwidth_gbps);
    let mut ring = Series::new("ring (2S(M-1)/M)");
    let mut tree = Series::new("tree (2S·ceil(log2 M))");
    let mut ps = Series::new("parameter server (2S(M-1) at server)");
    for m in [2usize, 4, 8, 16, 32] {
        let mf = m as f64;
        ring.push(mf, 2.0 * s_bytes * (mf - 1.0) / mf / rate);
        tree.push(mf, 2.0 * s_bytes * (mf as f64).log2().ceil() / rate);
        ps.push(mf, 2.0 * s_bytes * (mf - 1.0) / rate);
    }
    fig.series = vec![ring, tree, ps];
    fig
}

/// Bandwidth × compression grid: the full interaction the paper samples
/// at two bandwidths in Fig 8.
pub fn ablate_bw_compression_grid(model: ModelId) -> Figure {
    let mut fig = Figure::new(
        "ablate_bw_compression",
        format!("Scaling factor across bandwidth × compression ({}, full util)", model.name()),
        "bandwidth Gbps",
        "scaling factor",
    );
    let trace = backward_trace(&model.profile());
    for ratio in [1.0, 2.0, 5.0, 10.0, 50.0] {
        let mut s = Series::new(format!("{ratio}x"));
        for bw in [1.0, 5.0, 10.0, 25.0, 50.0, 100.0] {
            let mut p = SimParams::whatif(trace.clone(), 8, 8, bw);
            p.compression_ratio = ratio;
            s.push(bw, simulate(&p).scaling_factor);
        }
        fig.series.push(s);
    }
    fig
}

// NOTE: the authoritative "all ablations" enumeration is the registry's
// four `ablate-*` scenarios (engine::ScenarioRegistry::builtin) — there is
// deliberately no `all()` helper here to drift from it.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_size_has_interior_structure() {
        // Tiny buffers cost coordination per bucket; huge buffers delay
        // the first all-reduce. The defaults should beat at least one
        // extreme, and every point must be a valid fraction.
        let f = ablate_fusion_size(ModelId::Vgg16);
        let s = &f.series[0];
        for (x, y) in &s.points {
            assert!((0.0..=1.0).contains(y), "{x}: {y}");
        }
        let at_1 = s.y_at(1.0).unwrap();
        let at_64 = s.y_at(64.0).unwrap();
        assert!(at_64 >= at_1 - 0.05, "64MB {at_64} vs 1MB {at_1}");
    }

    #[test]
    fn losing_overlap_hurts_what_if_scaling() {
        // Isolate the paper's §4 claim "this overlap is critical": in the
        // idealized what-if with an effectively infinite buffer, a huge
        // timeout means nothing ships until backward ends — the scaling
        // factor must drop vs the 5 ms default. (In *measured* mode the
        // figure shows the opposite can happen: fewer buckets also means
        // less per-bucket negotiation — a real Horovod tuning tradeoff.)
        let trace = backward_trace(&ModelId::ResNet50.profile());
        let f = |timeout_s: f64| {
            let mut p = SimParams::whatif(trace.clone(), 8, 8, 25.0);
            p.fusion.buffer_bytes = 1 << 30; // no size triggers
            p.fusion.timeout_s = timeout_s;
            simulate(&p).scaling_factor
        };
        let overlapped = f(5e-3);
        let serial = f(1.0);
        assert!(serial < overlapped - 0.05, "{serial} vs {overlapped}");
    }

    #[test]
    fn ps_is_worst_at_scale() {
        let f = ablate_collective_cost(ModelId::Vgg16, 100.0);
        let ring = f.series("ring (2S(M-1)/M)").unwrap();
        let ps = f.series("parameter server (2S(M-1) at server)").unwrap();
        assert!(ps.y_at(32.0).unwrap() > ring.y_at(32.0).unwrap() * 10.0);
    }

    #[test]
    fn compression_grid_monotone_both_axes() {
        let f = ablate_bw_compression_grid(ModelId::Vgg16);
        // Along bandwidth at fixed ratio.
        for s in &f.series {
            for w in s.points.windows(2) {
                assert!(w[1].1 >= w[0].1 - 1e-9, "{}: {:?}", s.name, w);
            }
        }
        // Along ratio at fixed bandwidth.
        let at = |name: &str, bw: f64| f.series(name).unwrap().y_at(bw).unwrap();
        for bw in [1.0, 10.0] {
            assert!(at("2x", bw) >= at("1x", bw) - 1e-9);
            assert!(at("10x", bw) >= at("2x", bw) - 1e-9);
        }
    }
}
