//! Analytic mirror of the hierarchical (leader-ring) all-reduce —
//! [`crate::collectives::hierarchical`] as a cost model, the same way
//! [`crate::net::striped::StripedModel`] mirrors the striped transport.
//!
//! The question it answers is the paper's, one tier up: on a cluster
//! whose *aggregation* tier is oversubscribed, which all-reduce keeps the
//! provisioned hardware busy? A flat ring drags the full
//! `2·S·(N−1)/N` per-rank wire volume across the slowest link; the
//! pipelined ring's completion time is that volume over the bottleneck
//! rate. The hierarchical scheme pays three sequential phases instead —
//! intra-group ring at the fast tier, leader ring at the oversubscribed
//! tier (with only `2·S·(G−1)/G` crossing it), and an intra-group
//! broadcast:
//!
//! ```text
//! t_flat = 2·S·(N−1)/N / R_inter
//! t_hier = 2·S·(g−1)/g / R_intra  +  2·S·(G−1)/G / R_inter  +  S / R_intra
//! ```
//!
//! where `R_inter` is the *per-flow* rate through the oversubscribed tier
//! after the striped-transport software model
//! ([`StripedModel::effective_gbps`] at
//! [`Cluster::effective_inter_gbps`]), and `R_intra` is the intra-group
//! tier rate (NVLink-class — no kernel-TCP software ceiling). Both
//! strategies get the *same* transport on the inter tier, so the
//! comparison isolates the collective's topology-awareness: under full
//! bisection the extra phases make the hierarchy a slight loss, and as
//! oversubscription grows the leader ring's smaller inter-tier volume
//! wins — exactly the `hier_vs_flat` / `oversub_sweep` scenarios' shape.

use crate::net::striped::StripedModel;
use crate::topology::Cluster;

/// Cost model of flat vs hierarchical all-reduce on a two-tier cluster.
#[derive(Clone, Copy, Debug)]
pub struct HierModel {
    pub cluster: Cluster,
    /// Striped streams on the inter-group tier (1 = single kernel-TCP
    /// pipeline, the paper's broken transport).
    pub streams: usize,
}

impl HierModel {
    pub fn new(cluster: Cluster, streams: usize) -> HierModel {
        HierModel { cluster, streams: streams.max(1) }
    }

    /// Per-flow rate through the oversubscribed inter tier, after the
    /// striped transport's software model.
    pub fn inter_rate_gbps(&self) -> f64 {
        StripedModel::with_streams(self.streams)
            .effective_gbps(self.cluster.effective_inter_gbps())
    }

    /// Intra-group tier rate: NVLink-class, no kernel-TCP stack on the
    /// path, so the provisioned rate is the achieved rate.
    pub fn intra_rate_gbps(&self) -> f64 {
        self.cluster.intra_gbps
    }

    /// Ring-formula wire volume per rank over `parties`, seconds-free.
    fn ring_bytes(s_bytes: f64, parties: usize) -> f64 {
        crate::collectives::ring::wire_bytes_per_worker(s_bytes, parties)
    }

    /// Flat ring all-reduce time for `s_bytes`: the pipelined ring
    /// completes at its slowest link — the oversubscribed inter tier
    /// whenever the ring crosses groups.
    pub fn flat_time_s(&self, s_bytes: f64) -> f64 {
        let n = self.cluster.workers;
        if n <= 1 {
            return 0.0;
        }
        let rate = if self.cluster.n_groups() > 1 {
            self.inter_rate_gbps()
        } else {
            self.intra_rate_gbps()
        };
        Self::ring_bytes(s_bytes, n) / crate::gbps_to_bytes_per_sec(rate)
    }

    /// Hierarchical all-reduce time: intra ring + leader ring + broadcast
    /// (phases are sequential — the wire algorithm's structure).
    pub fn hier_time_s(&self, s_bytes: f64) -> f64 {
        let g = self.cluster.group_size.min(self.cluster.workers);
        let groups = self.cluster.n_groups();
        let intra_rate = crate::gbps_to_bytes_per_sec(self.intra_rate_gbps());
        let inter_rate = crate::gbps_to_bytes_per_sec(self.inter_rate_gbps());
        let mut t = Self::ring_bytes(s_bytes, g) / intra_rate;
        if groups > 1 {
            t += Self::ring_bytes(s_bytes, groups) / inter_rate;
            if g > 1 {
                t += s_bytes / intra_rate; // leader -> members broadcast
            }
        }
        t
    }

    /// NCCL-convention bus bandwidth: the ring-equivalent wire volume
    /// over the measured time, regardless of which algorithm ran — the
    /// normalization that makes strategies comparable.
    pub fn bus_gbps(&self, s_bytes: f64, time_s: f64) -> f64 {
        if time_s <= 0.0 {
            return 0.0;
        }
        crate::bytes_per_sec_to_gbps(Self::ring_bytes(s_bytes, self.cluster.workers) / time_s)
    }

    /// Flat-ring bus bandwidth at `s_bytes`.
    pub fn flat_bus_gbps(&self, s_bytes: f64) -> f64 {
        self.bus_gbps(s_bytes, self.flat_time_s(s_bytes))
    }

    /// Hierarchical bus bandwidth at `s_bytes`.
    pub fn hier_bus_gbps(&self, s_bytes: f64) -> f64 {
        self.bus_gbps(s_bytes, self.hier_time_s(s_bytes))
    }

    /// `t_flat / t_hier` — > 1 when the leader ring wins.
    pub fn speedup(&self, s_bytes: f64) -> f64 {
        let hier = self.hier_time_s(s_bytes);
        if hier <= 0.0 {
            return 1.0;
        }
        self.flat_time_s(s_bytes) / hier
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The ISSUE's acceptance topology: 4 groups x 4 ranks, 100 Gbps
    /// uplinks behind a 1:4-oversubscribed aggregation tier.
    fn four_by_four_oversub() -> HierModel {
        HierModel::new(Cluster::with_tiers(16, 4, 300.0, 100.0, 4.0), 8)
    }

    const S: f64 = 527e6; // VGG16-sized gradient

    #[test]
    fn hier_beats_flat_under_oversubscription() {
        let m = four_by_four_oversub();
        assert!(
            m.hier_time_s(S) < m.flat_time_s(S),
            "hier {} vs flat {}",
            m.hier_time_s(S),
            m.flat_time_s(S)
        );
        assert!(m.speedup(S) > 1.05, "{}", m.speedup(S));
        assert!(m.hier_bus_gbps(S) > m.flat_bus_gbps(S));
    }

    #[test]
    fn full_bisection_slightly_favors_flat() {
        // With no oversubscription the extra phases cost more than the
        // smaller leader-ring volume saves — hierarchy is a repair for
        // oversubscribed tiers, not a free win.
        let m = HierModel::new(Cluster::with_tiers(16, 4, 300.0, 100.0, 1.0), 8);
        assert!(m.speedup(S) < 1.0, "{}", m.speedup(S));
    }

    #[test]
    fn speedup_grows_with_oversubscription() {
        let mut last = 0.0;
        for oversub in [1.0, 2.0, 4.0, 8.0, 16.0] {
            let m = HierModel::new(Cluster::with_tiers(16, 4, 300.0, 100.0, oversub), 8);
            let s = m.speedup(S);
            assert!(s >= last, "oversub {oversub}: speedup {s} < {last}");
            last = s;
        }
        // The asymptote: wire(N)/wire(G) = (2·15/16)/(2·3/4) = 1.25.
        assert!(last < 1.25 + 1e-9);
        assert!(last > 1.15);
    }

    #[test]
    fn single_group_and_single_rank_degenerate() {
        let one_group = HierModel::new(Cluster::with_tiers(4, 8, 300.0, 100.0, 4.0), 8);
        // One group: hier == flat == an intra-tier ring.
        assert!((one_group.hier_time_s(S) - one_group.flat_time_s(S)).abs() < 1e-12);
        let solo = HierModel::new(Cluster::with_tiers(1, 1, 300.0, 100.0, 1.0), 8);
        assert_eq!(solo.flat_time_s(S), 0.0);
        assert_eq!(solo.hier_time_s(S), 0.0);
        assert_eq!(solo.speedup(S), 1.0);
    }

    #[test]
    fn bus_bandwidth_is_size_invariant() {
        // Pure rate model: time is linear in bytes, so busbw is flat
        // across message sizes (per-message overheads live in the
        // mechanistic path, not this mirror).
        let m = four_by_four_oversub();
        let a = m.hier_bus_gbps(1e6);
        let b = m.hier_bus_gbps(512e6);
        assert!((a - b).abs() / a < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn striping_raises_both_strategies() {
        let single = HierModel::new(Cluster::with_tiers(16, 4, 300.0, 100.0, 1.0), 1);
        let striped = HierModel::new(Cluster::with_tiers(16, 4, 300.0, 100.0, 1.0), 8);
        assert!(striped.hier_bus_gbps(S) > single.hier_bus_gbps(S));
        assert!(striped.flat_bus_gbps(S) > single.flat_bus_gbps(S));
    }
}
