//! The paper's §3 **what-if simulator**.
//!
//! Two virtual-time processes connected by a message queue, exactly as
//! §3.1 describes:
//!
//! * the **backward process** replays the white-box gradient-ready trace
//!   and batches tensors through the Horovod-style fusion buffer (64 MB /
//!   5 ms — the very same [`FusionBuffer`] state machine the real-time
//!   emulator uses);
//! * the **all-reduce process** drains buckets FIFO and charges each one
//!   the ring cost model: transit `= (2·S·(M−1)/M)/bw` over the `M`
//!   network parties (servers — the NIC is per server, and NCCL rings
//!   cross the network once per server) and vector adds
//!   `= (N−1)·AddEst(S/N)` over the `N` GPUs (§3.1's formula).
//!
//! The transport is pluggable: [`KernelTcpModel::ideal`] gives the
//! "what if the network were fully utilized" series; the calibrated
//! default plus two §2-derived imperfections — compute inflation (Fig 2's
//! ≤15% distributed-mode slowdown) and **communication contention** (the
//! transport's software ceiling drops while backward kernels run, which
//! is why measured overlap is imperfect) — give the Horovod-like
//! "measured" series. From `t_sync` and `t_back` the simulator derives
//! `t_overhead = t_sync − t_back` and the scaling factor
//! `f_sim = t_batch / (t_batch + t_overhead)` (§3.1).

pub mod ablation;
pub mod hier_model;
pub mod overlap_model;
pub mod whatif;

use crate::collectives::fusion::{Bucket, FusionBuffer, GradTensor};
use crate::config::FusionConfig;
use crate::models::timing::{AddEst, StepTrace};
use crate::net::kernel_tcp::KernelTcpModel;

/// Inputs of one simulation run.
#[derive(Clone, Debug)]
pub struct SimParams {
    /// White-box backward trace of one device (from
    /// [`crate::models::timing::backward_trace`] or recorded).
    pub trace: StepTrace,
    /// Network parties `M` in the inter-node ring (servers).
    pub servers: usize,
    /// GPUs per server; `N = servers × gpus_per_server` drives the
    /// vector-add cost.
    pub gpus_per_server: usize,
    /// Provisioned per-server bandwidth, Gbps.
    pub bandwidth_gbps: f64,
    /// Transport model (ideal or kernel-TCP-calibrated).
    pub transport: KernelTcpModel,
    pub fusion: FusionConfig,
    /// Wire-size divisor from gradient compression (§3.2 divides transit
    /// time by the ratio; the add cost intentionally stays uncompressed —
    /// the paper's stated simplification).
    pub compression_ratio: f64,
    pub add_est: AddEst,
    /// Computation-time inflation in distributed mode (Fig 2: hooks +
    /// in-stream all-reduce ops make distributed compute up to ~15%
    /// slower). 1.0 for the idealized what-if.
    pub compute_inflation: f64,
    /// Per-bucket coordination latency (Horovod's negotiation round).
    /// 0 for the idealized what-if.
    pub coord_latency_s: f64,
    /// Fraction of the transport's software ceiling lost while backward
    /// kernels are still running (imperfect overlap). 0 for the what-if.
    pub comm_contention: f64,
}

impl SimParams {
    /// Idealized what-if (§3.1): full utilization, no software overheads.
    pub fn whatif(
        trace: StepTrace,
        servers: usize,
        gpus_per_server: usize,
        bandwidth_gbps: f64,
    ) -> SimParams {
        SimParams {
            trace,
            servers,
            gpus_per_server,
            bandwidth_gbps,
            transport: KernelTcpModel::ideal(),
            fusion: FusionConfig::default(),
            compression_ratio: 1.0,
            add_est: AddEst::v100(),
            compute_inflation: 1.0,
            coord_latency_s: 0.0,
            comm_contention: 0.0,
        }
    }

    /// Horovod-like "measured" configuration: kernel-TCP transport,
    /// compute inflation, per-bucket coordination and backward-phase
    /// contention, calibrated against §2's measurements (see
    /// EXPERIMENTS.md §Calibration).
    pub fn horovod_like(
        trace: StepTrace,
        servers: usize,
        gpus_per_server: usize,
        bandwidth_gbps: f64,
    ) -> SimParams {
        SimParams {
            transport: KernelTcpModel::default(),
            compute_inflation: 1.12,
            coord_latency_s: 1.5e-3,
            comm_contention: 0.35,
            ..SimParams::whatif(trace, servers, gpus_per_server, bandwidth_gbps)
        }
    }

    /// Striped-transport configuration: the same distributed-software
    /// imperfections as [`SimParams::horovod_like`] (hooks still inflate
    /// compute, negotiation still costs latency, backward kernels still
    /// contend) — only the transport ceiling changes, because `streams`
    /// kernel-TCP pipelines now drain the NIC in parallel (see
    /// [`crate::net::striped::StripedModel`]). This is the simulator side
    /// of the `--transport striped:N` knob, kept apples-to-apples with
    /// the emulator's mechanistic striping.
    pub fn striped_like(
        trace: StepTrace,
        servers: usize,
        gpus_per_server: usize,
        bandwidth_gbps: f64,
        streams: usize,
    ) -> SimParams {
        SimParams {
            transport: crate::net::striped::StripedModel::with_streams(streams).to_kernel_model(),
            ..SimParams::horovod_like(trace, servers, gpus_per_server, bandwidth_gbps)
        }
    }

    /// Total GPUs.
    pub fn workers(&self) -> usize {
        self.servers * self.gpus_per_server
    }
}

/// Outputs of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Single-device batch time (denominator of the scaling factor).
    pub t_batch: f64,
    /// Backward duration in this run (after any inflation).
    pub t_back: f64,
    /// Time at which the all-reduce process finished the last bucket,
    /// relative to backward start.
    pub t_sync: f64,
    /// `t_sync − t_back` (§3.1).
    pub t_overhead: f64,
    /// `t_batch / (t_batch + t_overhead)` — with distributed compute
    /// inflation charged on top (see `simulate`).
    pub scaling_factor: f64,
    /// Number of fused buckets all-reduced.
    pub buckets: usize,
    /// Bytes each server's NIC carried (post-compression).
    pub wire_bytes_per_worker: f64,
    /// Mean achieved egress rate during the communication window, Gbps —
    /// feeds the Fig 4 utilization series.
    pub achieved_gbps: f64,
}

/// The all-reduce process's per-bucket cost parameters, shared by
/// [`simulate`] and [`overlap_model`]: coordination latency + `(N−1)`
/// vector adds + piecewise wire transit, with the transport's software
/// ceiling contended (reduced) while backward kernels still run.
pub(crate) struct DrainCost<'a> {
    /// Total GPUs `N` (vector-add parties).
    pub n: f64,
    /// Per-bucket wire-byte multiplier (`2(M−1)/M` for the inter-node ring).
    pub ring_factor: f64,
    pub inter_node: bool,
    pub multi_gpu: bool,
    /// Achieved bytes/s once backward has finished.
    pub rate_full: f64,
    /// Achieved bytes/s while backward still runs (contended ceiling).
    pub rate_backward: f64,
    pub per_msg_overhead_s: f64,
    pub coord_latency_s: f64,
    pub compression_ratio: f64,
    pub add_est: &'a AddEst,
    /// Aggregate wire bytes moved per chunk round across all stripes
    /// (`INFINITY` = unchunked: the pre-autotune behavior, charging no
    /// per-chunk cost).
    pub aggregate_chunk_bytes: f64,
    /// Software cost per stream-chunk (streams run in parallel, so this is
    /// charged once per chunk *round*).
    pub per_chunk_overhead_s: f64,
    /// Fraction of the final chunk's serialization that cannot overlap
    /// with delivery (store-and-forward tail; see
    /// [`crate::net::striped::StripedModel`]).
    pub chunk_tail_frac: f64,
}

impl<'a> DrainCost<'a> {
    pub(crate) fn from_sim(p: &'a SimParams) -> DrainCost<'a> {
        let m = p.servers as f64;
        let contended = KernelTcpModel {
            ceiling_gbps: p.transport.ceiling_gbps * (1.0 - p.comm_contention),
            ..p.transport
        };
        DrainCost {
            n: p.workers() as f64,
            ring_factor: if p.servers > 1 { 2.0 * (m - 1.0) / m } else { 0.0 },
            inter_node: p.servers > 1,
            multi_gpu: p.workers() > 1,
            rate_full: crate::gbps_to_bytes_per_sec(
                p.transport.effective_gbps(p.bandwidth_gbps),
            ),
            rate_backward: crate::gbps_to_bytes_per_sec(
                contended.effective_gbps(p.bandwidth_gbps),
            ),
            per_msg_overhead_s: p.transport.per_msg_overhead_s,
            coord_latency_s: p.coord_latency_s,
            compression_ratio: p.compression_ratio,
            add_est: &p.add_est,
            aggregate_chunk_bytes: f64::INFINITY,
            per_chunk_overhead_s: 0.0,
            chunk_tail_frac: 0.0,
        }
    }
}

/// Drain `(emit time, bucket bytes)` pairs FIFO through the all-reduce
/// process; returns `(finish time, wire bytes per worker)`. Wire bytes
/// drain piecewise across the backward/no-backward boundary at `t_back`.
pub(crate) fn drain_fifo(queue: &[(f64, f64)], t_back: f64, c: &DrainCost) -> (f64, f64) {
    let mut t_done = 0.0f64;
    let mut wire_bytes = 0.0f64;
    for (emit_t, bucket_bytes) in queue {
        let mut t = t_done.max(*emit_t);
        if !c.multi_gpu {
            t_done = t;
            continue;
        }
        // Coordination (negotiation) + vector adds: pure time.
        let elems_per_shard = bucket_bytes / 4.0 / c.n;
        t += c.coord_latency_s + (c.n - 1.0) * c.add_est.seconds(elems_per_shard);
        if c.inter_node {
            t += c.per_msg_overhead_s;
            let mut bytes = c.ring_factor * bucket_bytes / c.compression_ratio;
            wire_bytes += bytes;
            // Chunk-granularity costs (no-ops at the unchunked defaults):
            // every chunk round pays a fixed software cost, and the final
            // chunk's serialization partially fails to overlap delivery.
            if bytes > 0.0 {
                let rounds = (bytes / c.aggregate_chunk_bytes).ceil().max(1.0);
                t += rounds * c.per_chunk_overhead_s;
                t += c.chunk_tail_frac * bytes.min(c.aggregate_chunk_bytes) / c.rate_full;
            }
            while bytes > 0.0 {
                if t < t_back {
                    let can = (t_back - t) * c.rate_backward;
                    if can >= bytes {
                        t += bytes / c.rate_backward;
                        bytes = 0.0;
                    } else {
                        bytes -= can;
                        t = t_back;
                    }
                } else {
                    t += bytes / c.rate_full;
                    bytes = 0.0;
                }
            }
        }
        t_done = t;
    }
    (t_done, wire_bytes)
}

/// Run the two-process simulation once.
pub fn simulate(p: &SimParams) -> SimResult {
    assert!(p.servers >= 1 && p.gpus_per_server >= 1);
    // Finite too: a directly-constructed degenerate codec (k = 0) would
    // otherwise divide transit time by inf and silently report zero sync.
    assert!(p.compression_ratio.is_finite() && p.compression_ratio >= 1.0);
    assert!(p.compute_inflation >= 1.0);
    assert!((0.0..1.0).contains(&p.comm_contention));

    // ---- Backward process: replay trace through the fusion buffer. ----
    let infl = p.compute_inflation;
    let mut fusion = FusionBuffer::new(p.fusion);
    let mut queue: Vec<(f64, Bucket)> = Vec::new(); // (emit time, bucket)
    for ev in &p.trace.events {
        let t = ev.t_ready * infl;
        // Timeout may fire between events.
        while let Some(d) = fusion.deadline() {
            if d < t {
                if let Some(b) = fusion.poll(d) {
                    queue.push((d, b));
                }
            } else {
                break;
            }
        }
        for b in fusion.push(GradTensor::sized(ev.layer, ev.bytes), t) {
            queue.push((t, b));
        }
    }
    let t_back = p.trace.t_backward * infl;
    // End of backward: anything still pending flushes (possibly first via
    // a timeout that lands before the flush).
    while let Some(d) = fusion.deadline() {
        if d < t_back {
            if let Some(b) = fusion.poll(d) {
                queue.push((d, b));
            }
        } else {
            break;
        }
    }
    if let Some(b) = fusion.flush() {
        queue.push((t_back, b));
    }

    // ---- All-reduce process: FIFO over the message queue. ----
    let timeline: Vec<(f64, f64)> =
        queue.iter().map(|(t, b)| (*t, b.bytes as f64)).collect();
    let cost = DrainCost::from_sim(p);
    let (t_done, wire_bytes) = drain_fifo(&timeline, t_back, &cost);
    let t_sync = t_done.max(t_back);
    let t_overhead = t_sync - t_back;
    // Distributed compute inflation is itself overhead relative to the
    // single-GPU baseline: charge (infl−1)·t_batch alongside the sync gap.
    let t_batch = p.trace.t_batch;
    let denom = t_batch + t_overhead + (infl - 1.0) * t_batch;
    let scaling_factor = t_batch / denom;
    let achieved_gbps = if t_sync > 0.0 && p.servers > 1 {
        crate::bytes_per_sec_to_gbps(wire_bytes / t_sync)
    } else {
        0.0
    };
    SimResult {
        t_batch,
        t_back,
        t_sync,
        t_overhead,
        scaling_factor,
        buckets: queue.len(),
        wire_bytes_per_worker: wire_bytes,
        achieved_gbps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::timing::backward_trace;
    use crate::models::ModelId;

    fn trace(id: ModelId) -> StepTrace {
        backward_trace(&id.profile())
    }

    #[test]
    fn single_worker_is_perfect() {
        let r = simulate(&SimParams::whatif(trace(ModelId::ResNet50), 1, 1, 100.0));
        assert!((r.scaling_factor - 1.0).abs() < 1e-9, "{}", r.scaling_factor);
        assert_eq!(r.wire_bytes_per_worker, 0.0);
    }

    #[test]
    fn single_server_multi_gpu_is_near_perfect() {
        // All-NVLink: only the vector adds cost anything.
        let r = simulate(&SimParams::whatif(trace(ModelId::Vgg16), 1, 8, 100.0));
        assert!(r.scaling_factor > 0.9, "{}", r.scaling_factor);
        assert_eq!(r.wire_bytes_per_worker, 0.0);
    }

    #[test]
    fn whatif_100g_is_near_linear() {
        // Paper Fig 6/7: >99% for all three models at 100 Gbps, 64 GPUs.
        for id in ModelId::paper_models() {
            let r = simulate(&SimParams::whatif(trace(id), 8, 8, 100.0));
            assert!(r.scaling_factor > 0.95, "{id}: {}", r.scaling_factor);
        }
    }

    #[test]
    fn horovod_like_100g_matches_measured_band() {
        // Paper Fig 1 at 8 servers: ResNet50 71.6%, ResNet101 67.0%,
        // VGG16 59.8%. Shape requirements: ordering rn50 > rn101 > vgg16,
        // all within a generous 0.45–0.85 band around the paper's 56–76%.
        let f = |id| simulate(&SimParams::horovod_like(trace(id), 8, 8, 100.0)).scaling_factor;
        let (rn50, rn101, vgg) =
            (f(ModelId::ResNet50), f(ModelId::ResNet101), f(ModelId::Vgg16));
        assert!(rn50 > rn101 && rn101 > vgg, "{rn50} {rn101} {vgg}");
        for v in [rn50, rn101, vgg] {
            assert!((0.45..=0.85).contains(&v), "{v}");
        }
    }

    #[test]
    fn low_bandwidth_makes_both_agree() {
        // Paper Fig 6: at 1–10 Gbps the simulated and measured lines are
        // close (the wire, not the software, is the limit).
        for bw in [1.0, 10.0] {
            let a = simulate(&SimParams::whatif(trace(ModelId::ResNet50), 8, 8, bw));
            let b = simulate(&SimParams::horovod_like(trace(ModelId::ResNet50), 8, 8, bw));
            let rel = (a.scaling_factor - b.scaling_factor).abs() / a.scaling_factor;
            assert!(rel < 0.20, "bw={bw}: {} vs {}", a.scaling_factor, b.scaling_factor);
        }
    }

    #[test]
    fn divergence_grows_with_bandwidth() {
        let gap = |bw: f64| {
            let a = simulate(&SimParams::whatif(trace(ModelId::Vgg16), 8, 8, bw));
            let b = simulate(&SimParams::horovod_like(trace(ModelId::Vgg16), 8, 8, bw));
            a.scaling_factor - b.scaling_factor
        };
        assert!(gap(100.0) > gap(10.0) + 0.05, "gap(100)={} gap(10)={}", gap(100.0), gap(10.0));
    }

    #[test]
    fn scaling_monotone_in_bandwidth() {
        let mut last = 0.0;
        for bw in [1.0, 5.0, 10.0, 25.0, 50.0, 100.0] {
            let r = simulate(&SimParams::whatif(trace(ModelId::Vgg16), 8, 8, bw));
            assert!(r.scaling_factor >= last - 1e-9, "bw={bw}");
            last = r.scaling_factor;
        }
    }

    #[test]
    fn compression_helps_at_10g_not_100g() {
        // Paper Fig 8 + §3.2.
        let f = |bw: f64, ratio: f64| {
            let mut p = SimParams::whatif(trace(ModelId::Vgg16), 8, 8, bw);
            p.compression_ratio = ratio;
            simulate(&p).scaling_factor
        };
        assert!(f(10.0, 10.0) > 0.9, "{}", f(10.0, 10.0));
        assert!(f(10.0, 10.0) - f(10.0, 1.0) > 0.3);
        assert!(f(100.0, 10.0) - f(100.0, 1.0) < 0.05);
    }

    #[test]
    fn overhead_is_never_negative() {
        for (servers, gpus) in [(1usize, 1usize), (1, 8), (8, 8)] {
            for bw in [1.0, 100.0] {
                let r =
                    simulate(&SimParams::whatif(trace(ModelId::ResNet101), servers, gpus, bw));
                assert!(r.t_overhead >= -1e-12);
                assert!(r.t_sync >= r.t_back);
            }
        }
    }

    #[test]
    fn transmit_times_match_paper_discussion() {
        // §4: at 100 Gbps, transmitting all parameters takes 7.8 / 13.6 /
        // 42.2 ms for RN50 / RN101 / VGG16. (Pure S/bw, no ring factor.)
        let ms = |id: ModelId| {
            let s = id.profile().total_bytes() as f64;
            s / crate::gbps_to_bytes_per_sec(100.0) * 1e3
        };
        assert!((ms(ModelId::ResNet50) - 7.8).abs() < 0.8, "{}", ms(ModelId::ResNet50));
        assert!((ms(ModelId::ResNet101) - 13.6).abs() < 1.4, "{}", ms(ModelId::ResNet101));
        assert!((ms(ModelId::Vgg16) - 42.2).abs() < 3.0, "{}", ms(ModelId::Vgg16));
    }

    #[test]
    fn buckets_bounded_by_model_and_fusion() {
        let r = simulate(&SimParams::whatif(trace(ModelId::ResNet50), 2, 8, 100.0));
        // ~100 MB through a 64 MB buffer with 5 ms windows over ~60 ms of
        // backward: a handful of buckets, not hundreds.
        assert!((2..=40).contains(&r.buckets), "{}", r.buckets);
    }

    #[test]
    fn wire_bytes_match_hierarchical_ring_formula() {
        let r = simulate(&SimParams::whatif(trace(ModelId::ResNet50), 8, 8, 100.0));
        let s = ModelId::ResNet50.profile().total_bytes() as f64;
        let want = 2.0 * s * 7.0 / 8.0; // M = 8 servers
        assert!((r.wire_bytes_per_worker - want).abs() / want < 1e-6);
    }

    #[test]
    fn striped_recovers_scaling_at_100g() {
        // The tentpole claim, at the simulator level: same hardware, same
        // software imperfections, better transport — scaling factor moves
        // from the measured band toward linear.
        for id in ModelId::paper_models() {
            let single = simulate(&SimParams::horovod_like(trace(id), 8, 8, 100.0));
            let striped = simulate(&SimParams::striped_like(trace(id), 8, 8, 100.0, 8));
            assert!(
                striped.scaling_factor > single.scaling_factor + 0.08,
                "{id}: striped {} vs single {}",
                striped.scaling_factor,
                single.scaling_factor
            );
        }
    }

    #[test]
    fn striped_matches_single_when_wire_limited() {
        // At 1 Gbps the wire, not the software, is the limit: striping
        // cannot help (the paper's low-bandwidth regime).
        let single = simulate(&SimParams::horovod_like(trace(ModelId::ResNet50), 8, 8, 1.0));
        let striped = simulate(&SimParams::striped_like(trace(ModelId::ResNet50), 8, 8, 1.0, 8));
        let rel = (single.scaling_factor - striped.scaling_factor).abs() / single.scaling_factor;
        assert!(rel < 0.05, "{} vs {}", single.scaling_factor, striped.scaling_factor);
    }

    #[test]
    fn contention_only_hurts_at_high_bandwidth() {
        // At 1 Gbps the wire is the limit either way; at 100 Gbps the
        // contended ceiling bites.
        let f = |bw: f64, contention: f64| {
            let mut p = SimParams::horovod_like(trace(ModelId::ResNet50), 8, 8, bw);
            p.comm_contention = contention;
            simulate(&p).scaling_factor
        };
        let low_gap = f(1.0, 0.0) - f(1.0, 0.5);
        let high_gap = f(100.0, 0.0) - f(100.0, 0.5);
        assert!(low_gap < 0.02, "{low_gap}");
        assert!(high_gap > 0.03, "{high_gap}");
    }
}
