//! Analytic mirror of the overlap scheduler — [`crate::sched`] as a cost
//! model, the same way [`crate::sim::hier_model`] mirrors the leader-ring
//! collective and [`crate::net::striped::StripedModel`] mirrors the
//! striped transport.
//!
//! The intuition is the textbook one: with perfect pipelining the step
//! costs `max(compute, comm)` plus a non-overlappable head (no gradient
//! exists before the first bucket's layers finish) and tail (the last
//! bucket can only ship after backward ends). The model computes that
//! exactly rather than approximately: buckets come from the same
//! size-threshold bucketizer the real scheduler uses
//! ([`crate::sched::bucket::bucket_timeline_from_trace`]) and drain FIFO
//! through the same piecewise cost loop as [`crate::sim::simulate`]
//! (coordination + vector adds + contended-then-full wire rate), so the
//! mirror composes with [`KernelTcpModel`] and
//! [`crate::net::striped::StripedModel::to_kernel_model`] — and, via the
//! flat/hier rate choice, with [`crate::sim::hier_model::HierModel`]'s
//! cluster tiers.
//!
//! `--overlap off` is the same queue with every emit time pushed to the
//! end of backward: identical work, zero overlap — the blocking baseline
//! the `overlap_ablation` and `scaling_factor_recovered` scenarios
//! compare against.

use super::{drain_fifo, DrainCost};
use crate::config::OverlapMode;
use crate::models::timing::{AddEst, StepTrace};
use crate::net::kernel_tcp::KernelTcpModel;
use crate::sched::bucket::{bucket_timeline_from_trace, mb_to_threshold};

/// Chunk-granularity cost model for the striped transport's pipelining
/// unit — the analytic face of the `chunk_kb` knob the autotuner turns.
/// Tiny chunks pay `per_chunk_s` once per chunk round; huge chunks lose
/// store-and-forward overlap through `tail_frac` (mirroring
/// [`crate::net::striped::StripedModel::transfer_time_chunked`]).
#[derive(Clone, Copy, Debug)]
pub struct Chunking {
    /// Wire bytes per chunk round, aggregated across all stripes
    /// (`per-stream chunk × streams`).
    pub aggregate_chunk_bytes: f64,
    /// Fixed software cost per chunk round.
    pub per_chunk_s: f64,
    /// Fraction of the final chunk's serialization that cannot overlap
    /// with delivery.
    pub tail_frac: f64,
}

impl Chunking {
    /// The striped transport's calibrated chunk costs at a given
    /// per-stream chunk size (see [`crate::net::striped::StripedModel`]).
    pub fn striped(streams: usize, chunk_bytes: usize) -> Chunking {
        let m = crate::net::striped::StripedModel::with_streams(streams.max(1));
        Chunking {
            aggregate_chunk_bytes: (chunk_bytes * streams.max(1)) as f64,
            per_chunk_s: m.per_chunk_overhead_s,
            tail_frac: m.delivery_tail_frac,
        }
    }
}

/// Inputs of one overlap-model evaluation.
#[derive(Clone, Debug)]
pub struct OverlapModelParams {
    pub trace: StepTrace,
    /// Network parties `M` in the inter-node ring (servers).
    pub servers: usize,
    pub gpus_per_server: usize,
    /// Provisioned per-server bandwidth, Gbps.
    pub bandwidth_gbps: f64,
    /// Transport model; use [`KernelTcpModel::ideal`] for the
    /// full-utilization bound or a striped model's `to_kernel_model()`.
    pub transport: KernelTcpModel,
    pub mode: OverlapMode,
    /// Bucketizer threshold in bytes (0 = one bucket holding the whole
    /// gradient — maximal fusion, minimal overlap).
    pub bucket_bytes: usize,
    pub compression_ratio: f64,
    pub add_est: AddEst,
    /// Distributed-mode compute inflation (1.0 for the idealized bound).
    pub compute_inflation: f64,
    /// Per-bucket coordination latency.
    pub coord_latency_s: f64,
    /// Fractional transport-ceiling loss while backward kernels run.
    pub comm_contention: f64,
    /// Chunk-granularity costs (`None` = unchunked, the pre-autotune
    /// behavior). The autotuning oracle sets this from the `chunk_kb`
    /// knob.
    pub chunking: Option<Chunking>,
    /// Per-bucket wire-byte multiplier override (`None` = the inter-node
    /// ring's `2(M−1)/M`). Lets the oracle price non-ring collectives
    /// without changing the drain loop.
    pub wire_factor: Option<f64>,
}

impl OverlapModelParams {
    /// The **analytic full-utilization bound** (§3.1's what-if, with
    /// overlap): ideal transport, no software imperfections. This is the
    /// ceiling `scaling_factor_recovered` measures recovery against.
    pub fn ideal_bound(
        trace: StepTrace,
        servers: usize,
        gpus_per_server: usize,
        bandwidth_gbps: f64,
        bucket_mb: f64,
    ) -> OverlapModelParams {
        OverlapModelParams {
            trace,
            servers,
            gpus_per_server,
            bandwidth_gbps,
            transport: KernelTcpModel::ideal(),
            mode: OverlapMode::Buckets,
            bucket_bytes: mb_to_threshold(bucket_mb),
            compression_ratio: 1.0,
            add_est: AddEst::v100(),
            compute_inflation: 1.0,
            coord_latency_s: 0.0,
            comm_contention: 0.0,
            chunking: None,
            wire_factor: None,
        }
    }

    /// The overlap **engine** running on real distributed software:
    /// per-bucket negotiation and backward-phase contention as in
    /// [`super::SimParams::horovod_like`], but milder compute inflation
    /// (1.05 vs the hook-driven 1.12) because the engine's background
    /// thread replaces Horovod's in-stream blocking all-reduce ops — the
    /// hooks remain, the stalls go.
    pub fn engine(
        trace: StepTrace,
        servers: usize,
        gpus_per_server: usize,
        bandwidth_gbps: f64,
        transport: KernelTcpModel,
        bucket_mb: f64,
    ) -> OverlapModelParams {
        OverlapModelParams {
            transport,
            mode: OverlapMode::Buckets,
            compute_inflation: 1.05,
            coord_latency_s: 1.5e-3,
            comm_contention: 0.35,
            ..OverlapModelParams::ideal_bound(
                trace,
                servers,
                gpus_per_server,
                bandwidth_gbps,
                bucket_mb,
            )
        }
    }

    /// Total GPUs.
    pub fn workers(&self) -> usize {
        self.servers * self.gpus_per_server
    }
}

/// Outputs of one overlap-model evaluation.
#[derive(Clone, Copy, Debug)]
pub struct OverlapModelResult {
    /// Single-device batch time (scaling-factor denominator).
    pub t_batch: f64,
    /// Backward duration after inflation.
    pub t_back: f64,
    /// All-reduce completion, relative to backward start.
    pub t_sync: f64,
    /// `t_sync − t_back`: the serialization the overlap failed to hide.
    pub t_overhead: f64,
    /// Distributed step time: forward + backward (inflated) + overhead.
    pub step_time_s: f64,
    /// `t_batch / (t_batch + t_overhead + (infl−1)·t_batch)` (§3.1 shape).
    pub scaling_factor: f64,
    /// Serialized communication time of the same queue (the `comm` leg of
    /// `max(compute, comm)` — what a zero-overlap run would append).
    pub t_comm_s: f64,
    pub buckets: usize,
}

/// Evaluate one overlapped (or blocking) step.
pub fn overlap_step(p: &OverlapModelParams) -> OverlapModelResult {
    assert!(p.servers >= 1 && p.gpus_per_server >= 1);
    assert!(p.compute_inflation >= 1.0);
    assert!((0.0..1.0).contains(&p.comm_contention));
    assert!(p.compression_ratio.is_finite() && p.compression_ratio >= 1.0);
    let infl = p.compute_inflation;
    let t_back = p.trace.t_backward * infl;

    // Bucket queue from the scheduler's own bucketizer, emit times
    // inflated with the compute they depend on; blocking mode pushes
    // every emission to the end of backward.
    let timeline = bucket_timeline_from_trace(&p.trace, p.bucket_bytes);
    let queue: Vec<(f64, f64)> = timeline
        .iter()
        .map(|(t, bytes)| {
            let emit = match p.mode {
                OverlapMode::Buckets => t * infl,
                OverlapMode::Off => t_back,
            };
            (emit, *bytes as f64)
        })
        .collect();

    let sim = super::SimParams {
        trace: p.trace.clone(),
        servers: p.servers,
        gpus_per_server: p.gpus_per_server,
        bandwidth_gbps: p.bandwidth_gbps,
        transport: p.transport,
        fusion: crate::config::FusionConfig::default(),
        compression_ratio: p.compression_ratio,
        add_est: p.add_est.clone(),
        compute_inflation: p.compute_inflation,
        coord_latency_s: p.coord_latency_s,
        comm_contention: p.comm_contention,
    };
    let mut cost = DrainCost::from_sim(&sim);
    if let Some(ch) = p.chunking {
        assert!(ch.aggregate_chunk_bytes > 0.0 && ch.per_chunk_s >= 0.0);
        assert!((0.0..=1.0).contains(&ch.tail_frac));
        cost.aggregate_chunk_bytes = ch.aggregate_chunk_bytes;
        cost.per_chunk_overhead_s = ch.per_chunk_s;
        cost.chunk_tail_frac = ch.tail_frac;
    }
    if let Some(f) = p.wire_factor {
        assert!(f.is_finite() && f >= 0.0);
        if cost.inter_node {
            cost.ring_factor = f;
        }
    }
    let (t_done, _) = drain_fifo(&queue, t_back, &cost);
    let t_sync = t_done.max(t_back);
    let t_overhead = t_sync - t_back;

    // The serialized-comm reference: same buckets, all available at t=0,
    // no backward window to contend with.
    let serial: Vec<(f64, f64)> = queue.iter().map(|(_, b)| (0.0, *b)).collect();
    let (t_comm_s, _) = drain_fifo(&serial, 0.0, &cost);

    let t_batch = p.trace.t_batch;
    let denom = t_batch + t_overhead + (infl - 1.0) * t_batch;
    OverlapModelResult {
        t_batch,
        t_back,
        t_sync,
        t_overhead,
        step_time_s: t_batch * infl + t_overhead,
        scaling_factor: t_batch / denom,
        t_comm_s,
        buckets: queue.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::timing::backward_trace;
    use crate::models::ModelId;
    use crate::net::striped::StripedModel;

    fn trace(id: ModelId) -> StepTrace {
        backward_trace(&id.profile())
    }

    fn engine_at(
        id: ModelId,
        bw: f64,
        streams: usize,
        mode: OverlapMode,
        bucket_mb: f64,
    ) -> OverlapModelResult {
        let transport = if streams > 1 {
            StripedModel::with_streams(streams).to_kernel_model()
        } else {
            KernelTcpModel::default()
        };
        let mut p = OverlapModelParams::engine(trace(id), 8, 8, bw, transport, bucket_mb);
        p.mode = mode;
        overlap_step(&p)
    }

    #[test]
    fn overlap_never_slower_than_blocking() {
        for id in ModelId::paper_models() {
            for bw in [1.0, 10.0, 100.0] {
                let on = engine_at(id, bw, 8, OverlapMode::Buckets, 25.0);
                let off = engine_at(id, bw, 8, OverlapMode::Off, 25.0);
                assert!(
                    on.step_time_s <= off.step_time_s + 1e-12,
                    "{id} @ {bw}G: overlapped {} > blocking {}",
                    on.step_time_s,
                    off.step_time_s
                );
            }
        }
    }

    #[test]
    fn overlap_wins_decisively_when_comm_fits_under_backward() {
        // ResNet50 at 100 Gbps striped: comm (~tens of ms) hides almost
        // entirely under backward — blocking pays it in full.
        let on = engine_at(ModelId::ResNet50, 100.0, 8, OverlapMode::Buckets, 25.0);
        let off = engine_at(ModelId::ResNet50, 100.0, 8, OverlapMode::Off, 25.0);
        assert!(on.t_comm_s < on.t_back, "comm {} should fit under {}", on.t_comm_s, on.t_back);
        assert!(off.step_time_s > on.step_time_s * 1.05, "{} vs {}", off.step_time_s, on.step_time_s);
        assert!(on.scaling_factor > off.scaling_factor + 0.03);
    }

    #[test]
    fn ideal_bound_dominates_engine() {
        for id in ModelId::paper_models() {
            let bound = overlap_step(&OverlapModelParams::ideal_bound(
                trace(id),
                8,
                8,
                100.0,
                25.0,
            ));
            let engine = engine_at(id, 100.0, 8, OverlapMode::Buckets, 25.0);
            assert!(bound.scaling_factor >= engine.scaling_factor - 1e-12, "{id}");
            assert!(bound.scaling_factor > 0.9, "{id}: bound {}", bound.scaling_factor);
        }
    }

    #[test]
    fn recovery_claim_shape_at_100g() {
        // The scaling_factor_recovered acceptance shape: overlap + striped
        // reaches >= 0.9 of the full-utilization bound; blocking + single
        // stream does not.
        let bound = overlap_step(&OverlapModelParams::ideal_bound(
            trace(ModelId::ResNet50),
            8,
            8,
            100.0,
            25.0,
        ));
        let recovered = engine_at(ModelId::ResNet50, 100.0, 8, OverlapMode::Buckets, 25.0);
        let broken = {
            let mut p = OverlapModelParams::engine(
                trace(ModelId::ResNet50),
                8,
                8,
                100.0,
                KernelTcpModel::default(),
                25.0,
            );
            p.mode = OverlapMode::Off;
            p.compute_inflation = 1.12; // Horovod's hook-driven inflation
            overlap_step(&p)
        };
        assert!(
            recovered.scaling_factor >= 0.9 * bound.scaling_factor,
            "recovered {} vs bound {}",
            recovered.scaling_factor,
            bound.scaling_factor
        );
        assert!(
            broken.scaling_factor < 0.9 * bound.scaling_factor,
            "broken {} vs bound {}",
            broken.scaling_factor,
            bound.scaling_factor
        );
    }

    #[test]
    fn bucket_size_has_interior_optimum() {
        // The regime where the trade is visible: at 5 Gbps communication
        // exceeds backward, so every extra bucket's coordination adds to
        // the un-hidden overhead (too small loses) while one huge bucket
        // forfeits all overlap (too large loses). At high rates comm
        // hides entirely and finer buckets would win outright.
        let step = |mb: f64| engine_at(ModelId::Vgg16, 5.0, 8, OverlapMode::Buckets, mb).step_time_s;
        let sweep: Vec<f64> = [0.05, 1.0, 4.0, 16.0, 64.0, 600.0].iter().map(|mb| step(*mb)).collect();
        let best = sweep
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        assert!(best != 0 && best != sweep.len() - 1, "optimum at boundary: {sweep:?}");
    }

    #[test]
    fn chunking_has_an_interior_optimum() {
        // The chunk_kb knob's analytic face: tiny chunks drown in
        // per-chunk software cost, huge chunks lose delivery overlap.
        let step = |chunk_kb: usize| {
            let mut p = OverlapModelParams::engine(
                trace(ModelId::ResNet50),
                8,
                8,
                10.0,
                StripedModel::with_streams(8).to_kernel_model(),
                16.0,
            );
            p.chunking = Some(Chunking::striped(8, chunk_kb << 10));
            overlap_step(&p).step_time_s
        };
        let tiny = step(4);
        let mid = step(256);
        let huge = step(16384);
        assert!(mid < tiny, "mid {mid} vs tiny {tiny}");
        assert!(mid < huge, "mid {mid} vs huge {huge}");
        // And the unchunked model is a lower bound on all of them.
        let mut p = OverlapModelParams::engine(
            trace(ModelId::ResNet50),
            8,
            8,
            10.0,
            StripedModel::with_streams(8).to_kernel_model(),
            16.0,
        );
        p.chunking = None;
        assert!(overlap_step(&p).step_time_s <= mid + 1e-12);
    }

    #[test]
    fn wire_factor_override_scales_comm() {
        let base = OverlapModelParams::engine(
            trace(ModelId::Vgg16),
            8,
            8,
            5.0,
            KernelTcpModel::default(),
            16.0,
        );
        let mut heavy = base.clone();
        heavy.wire_factor = Some(4.0); // > ring's 2·7/8 = 1.75
        let a = overlap_step(&base);
        let b = overlap_step(&heavy);
        assert!(b.step_time_s > a.step_time_s, "{} vs {}", b.step_time_s, a.step_time_s);
        // Zero wire factor degenerates to a no-wire run.
        let mut none = base.clone();
        none.wire_factor = Some(0.0);
        assert!(overlap_step(&none).step_time_s < a.step_time_s);
    }

    #[test]
    fn single_worker_degenerates_cleanly() {
        let p = OverlapModelParams::ideal_bound(trace(ModelId::ResNet50), 1, 1, 100.0, 25.0);
        let r = overlap_step(&p);
        assert!((r.scaling_factor - 1.0).abs() < 1e-9);
        assert!(r.t_overhead.abs() < 1e-12);
    }

    #[test]
    fn mirrors_simulate_under_fusion_free_config() {
        // Same physics as `simulate` (shared drain loop): overheads are
        // non-negative and sync never precedes backward.
        for mode in [OverlapMode::Off, OverlapMode::Buckets] {
            for servers in [1usize, 2, 8] {
                let mut p = OverlapModelParams::ideal_bound(
                    trace(ModelId::ResNet101),
                    servers,
                    8,
                    25.0,
                    16.0,
                );
                p.mode = mode;
                let r = overlap_step(&p);
                assert!(r.t_overhead >= -1e-12);
                assert!(r.t_sync >= r.t_back - 1e-12);
                assert!(r.buckets >= 1);
            }
        }
    }
}
