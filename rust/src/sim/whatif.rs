//! What-if sweep drivers: every figure in the paper's evaluation as a
//! regenerable data series. Each function returns [`Figure`]s containing
//! exactly the rows/series the paper plots; the shape checks that go with
//! them live in [`crate::figures`].

use super::{simulate, SimParams};
use crate::models::timing::backward_trace;
use crate::models::ModelId;
use crate::net::kernel_tcp::KernelTcpModel;
use crate::report::{Figure, Series};

/// Default GPUs per server (p3dn.24xlarge).
pub const GPUS_PER_SERVER: usize = 8;
/// The paper's bandwidth sweep points (Gbps).
pub const BANDWIDTHS: [f64; 5] = [1.0, 10.0, 25.0, 50.0, 100.0];
/// The paper's server sweep points.
pub const SERVER_COUNTS: [usize; 3] = [2, 4, 8];

/// Fig 1 — scaling factor vs number of servers (Horovod-like transport at
/// 100 Gbps), one series per model.
pub fn fig1_scaling_vs_servers() -> Figure {
    let mut fig = Figure::new(
        "fig1",
        "Scaling factor vs. number of servers (measured-mode, 100 Gbps)",
        "servers",
        "scaling factor",
    );
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut s = Series::new(id.name());
        for servers in SERVER_COUNTS {
            let p = SimParams::horovod_like(trace.clone(), servers, GPUS_PER_SERVER, 100.0);
            s.push(servers as f64, simulate(&p).scaling_factor);
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 2 — computation time (ms per batch) vs number of servers, one
/// series per model, plus the single-GPU baseline at x = 1.
pub fn fig2_computation_time() -> Figure {
    let mut fig = Figure::new(
        "fig2",
        "Computation time vs. number of servers",
        "servers",
        "computation ms/batch",
    );
    for id in ModelId::paper_models() {
        let profile = id.profile();
        let mut s = Series::new(id.name());
        // Single GPU: no hooks, no in-stream all-reduce ops.
        s.push(1.0, profile.t_batch() * 1e3);
        for servers in SERVER_COUNTS {
            let p = SimParams::horovod_like(
                backward_trace(&profile),
                servers,
                GPUS_PER_SERVER,
                100.0,
            );
            // Distributed computation phase = inflated t_batch; constant in
            // the number of servers (the paper's point).
            s.push(servers as f64, profile.t_batch() * p.compute_inflation * 1e3);
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 3 — scaling factor vs bandwidth for one model (paper: ResNet50),
/// one series per server count, measured-mode transport.
pub fn fig3_scaling_vs_bandwidth(model: ModelId) -> Figure {
    let mut fig = Figure::new(
        "fig3",
        format!("Scaling factor vs. bandwidth ({}, measured-mode)", model.name()),
        "bandwidth Gbps",
        "scaling factor",
    );
    let trace = backward_trace(&model.profile());
    for servers in SERVER_COUNTS {
        let mut s = Series::new(format!("{servers} servers"));
        for bw in BANDWIDTHS {
            let p = SimParams::horovod_like(trace.clone(), servers, GPUS_PER_SERVER, bw);
            s.push(bw, simulate(&p).scaling_factor);
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 4 — network bandwidth utilization vs provisioned bandwidth.
/// Two views per model: the transport model's achievable utilization and
/// the achieved-over-communication-window rate from the simulation.
pub fn fig4_network_utilization() -> Figure {
    let mut fig = Figure::new(
        "fig4",
        "Network bandwidth utilization vs. provisioned bandwidth (8 servers)",
        "bandwidth Gbps",
        "utilization (fraction)",
    );
    let transport = KernelTcpModel::default();
    let mut cap = Series::new("transport achievable");
    for bw in BANDWIDTHS {
        cap.push(bw, transport.utilization(bw));
    }
    fig.series.push(cap);
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut s = Series::new(format!("{} achieved", id.name()));
        for bw in BANDWIDTHS {
            let p = SimParams::horovod_like(trace.clone(), 8, GPUS_PER_SERVER, bw);
            let r = simulate(&p);
            s.push(bw, (r.achieved_gbps / bw).min(1.0));
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 4, **recovered**: the same utilization axes with the striped
/// transport next to the broken single-stream one — the paper's thesis
/// shown constructively (same hardware, better transport, utilization
/// climbing back toward the provisioned line).
pub fn fig4_recovered_utilization(streams: usize) -> Figure {
    let mut fig = Figure::new(
        "fig4_recovered",
        format!(
            "Network utilization vs. provisioned bandwidth: single-stream vs striped:{streams} (8 servers)"
        ),
        "bandwidth Gbps",
        "utilization (fraction)",
    );
    let single = KernelTcpModel::default();
    let striped = crate::net::striped::StripedModel::with_streams(streams);
    let mut s_single = Series::new("single-stream achievable");
    let mut s_striped = Series::new(format!("striped:{streams} achievable"));
    for bw in BANDWIDTHS {
        s_single.push(bw, single.utilization(bw));
        s_striped.push(bw, striped.utilization(bw));
    }
    fig.series.push(s_single);
    fig.series.push(s_striped);
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut s = Series::new(format!("{} achieved (striped:{streams})", id.name()));
        for bw in BANDWIDTHS {
            let p = SimParams::striped_like(trace.clone(), 8, GPUS_PER_SERVER, bw, streams);
            let r = simulate(&p);
            s.push(bw, (r.achieved_gbps / bw).min(1.0));
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 5 — CPU utilization during the communication phase vs network
/// speed, one series per model (8 servers).
pub fn fig5_cpu_utilization() -> Figure {
    let mut fig = Figure::new(
        "fig5",
        "CPU utilization vs. network speed (8 servers)",
        "bandwidth Gbps",
        "CPU utilization (fraction)",
    );
    let transport = KernelTcpModel::default();
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut s = Series::new(id.name());
        for bw in BANDWIDTHS {
            let p = SimParams::horovod_like(trace.clone(), 8, GPUS_PER_SERVER, bw);
            let r = simulate(&p);
            // CPU cost follows the achieved wire rate; duty-cycle weights
            // it by how much of the step the communication phase occupies.
            let duty = (r.t_sync - 0.0).min(r.t_batch + r.t_overhead) / (r.t_batch + r.t_overhead);
            s.push(bw, transport.cpu_utilization(bw) * duty.clamp(0.0, 1.0));
        }
        fig.series.push(s);
    }
    fig
}

/// Fig 6 — simulated (full-utilization) vs measured-mode scaling factor
/// across bandwidths; one figure per model (8 servers, as the paper's
/// divergence analysis).
pub fn fig6_sim_vs_measured(model: ModelId, servers: usize) -> Figure {
    let mut fig = Figure::new(
        format!("fig6_{}", model.name().to_ascii_lowercase()),
        format!("Simulated vs measured scaling factor ({}, {servers} servers)", model.name()),
        "bandwidth Gbps",
        "scaling factor",
    );
    let trace = backward_trace(&model.profile());
    let mut sim_s = Series::new("simulated (full util)");
    let mut meas_s = Series::new("measured-mode (Horovod-like)");
    for bw in BANDWIDTHS {
        sim_s.push(
            bw,
            simulate(&SimParams::whatif(trace.clone(), servers, GPUS_PER_SERVER, bw))
                .scaling_factor,
        );
        meas_s.push(
            bw,
            simulate(&SimParams::horovod_like(trace.clone(), servers, GPUS_PER_SERVER, bw))
                .scaling_factor,
        );
    }
    fig.series = vec![sim_s, meas_s];
    fig
}

/// Fig 7 — simulated scaling factor under 100 Gbps vs number of workers,
/// with the measured-mode value alongside (the paper's red "gap" bars).
pub fn fig7_simulated_at_100g() -> Figure {
    let mut fig = Figure::new(
        "fig7",
        "Simulated scaling factor under 100 Gbps (gap to measured-mode)",
        "workers (GPUs)",
        "scaling factor",
    );
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut sim_s = Series::new(format!("{} simulated", id.name()));
        let mut meas_s = Series::new(format!("{} measured", id.name()));
        for servers in SERVER_COUNTS {
            let w = servers * GPUS_PER_SERVER;
            sim_s.push(
                w as f64,
                simulate(&SimParams::whatif(trace.clone(), servers, GPUS_PER_SERVER, 100.0))
                    .scaling_factor,
            );
            meas_s.push(
                w as f64,
                simulate(&SimParams::horovod_like(trace.clone(), servers, GPUS_PER_SERVER, 100.0))
                    .scaling_factor,
            );
        }
        fig.series.push(sim_s);
        fig.series.push(meas_s);
    }
    fig
}

/// The paper's compression-ratio sweep points.
pub const COMPRESSION_RATIOS: [f64; 6] = [1.0, 2.0, 4.0, 5.0, 10.0, 100.0];

/// Fig 8 — simulated scaling factor vs gradient-compression ratio at a
/// given bandwidth (paper shows 10 Gbps and 100 Gbps), full utilization,
/// one series per model (8 servers).
pub fn fig8_compression(bandwidth_gbps: f64) -> Figure {
    let mut fig = Figure::new(
        format!("fig8_{}g", bandwidth_gbps as u64),
        format!("Simulated scaling factor vs compression ratio ({bandwidth_gbps} Gbps)"),
        "compression ratio",
        "scaling factor",
    );
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let mut s = Series::new(id.name());
        for ratio in COMPRESSION_RATIOS {
            let mut p = SimParams::whatif(trace.clone(), 8, GPUS_PER_SERVER, bandwidth_gbps);
            p.compression_ratio = ratio;
            s.push(ratio, simulate(&p).scaling_factor);
        }
        fig.series.push(s);
    }
    fig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_three_models_three_points() {
        let f = fig1_scaling_vs_servers();
        assert_eq!(f.series.len(), 3);
        for s in &f.series {
            assert_eq!(s.points.len(), 3);
            for (_, y) in &s.points {
                assert!((0.3..1.0).contains(y), "{}: {y}", s.name);
            }
        }
    }

    #[test]
    fn fig1_ordering_resnet50_best_vgg_worst() {
        let f = fig1_scaling_vs_servers();
        for servers in SERVER_COUNTS {
            let x = servers as f64;
            let rn50 = f.series("ResNet50").unwrap().y_at(x).unwrap();
            let rn101 = f.series("ResNet101").unwrap().y_at(x).unwrap();
            let vgg = f.series("VGG16").unwrap().y_at(x).unwrap();
            assert!(rn50 > rn101 && rn101 > vgg, "{servers}: {rn50} {rn101} {vgg}");
        }
    }

    #[test]
    fn fig2_flat_in_servers() {
        let f = fig2_computation_time();
        for s in &f.series {
            let at2 = s.y_at(2.0).unwrap();
            let at8 = s.y_at(8.0).unwrap();
            assert!((at2 - at8).abs() < 1e-9, "{}", s.name);
            // Distributed ≤ 15% above single GPU (paper's bound).
            let single = s.y_at(1.0).unwrap();
            assert!(at8 / single <= 1.15 + 1e-9);
            assert!(at8 / single > 1.0);
        }
    }

    #[test]
    fn fig3_plateaus_after_25g() {
        let f = fig3_scaling_vs_bandwidth(ModelId::ResNet50);
        for s in &f.series {
            let gain_low = s.y_at(10.0).unwrap() - s.y_at(1.0).unwrap();
            let gain_high = s.y_at(100.0).unwrap() - s.y_at(25.0).unwrap();
            assert!(gain_high < gain_low * 0.4, "{}: {gain_low} vs {gain_high}", s.name);
        }
    }

    #[test]
    fn fig4_full_at_1g_low_at_100g() {
        let f = fig4_network_utilization();
        let cap = f.series("transport achievable").unwrap();
        assert!(cap.y_at(1.0).unwrap() > 0.99);
        assert!(cap.y_at(100.0).unwrap() < 0.35);
    }

    #[test]
    fn fig4_recovered_restores_utilization() {
        let f = fig4_recovered_utilization(8);
        let single = f.series("single-stream achievable").unwrap();
        let striped = f.series("striped:8 achievable").unwrap();
        // Both near-full at 1 Gbps; only the striped one stays high.
        assert!(single.y_at(1.0).unwrap() > 0.99);
        assert!(striped.y_at(1.0).unwrap() > 0.99);
        assert!(single.y_at(100.0).unwrap() < 0.35);
        assert!(striped.y_at(100.0).unwrap() > 0.85);
        // Striped dominates single at every provisioned rate.
        for bw in BANDWIDTHS {
            assert!(striped.y_at(bw).unwrap() + 1e-12 >= single.y_at(bw).unwrap(), "{bw}");
        }
    }

    #[test]
    fn fig5_in_paper_band() {
        let f = fig5_cpu_utilization();
        for s in &f.series {
            for (bw, u) in &s.points {
                assert!((0.0..=0.30).contains(u), "{} @ {bw}: {u}", s.name);
            }
        }
    }

    #[test]
    fn fig6_diverges_at_high_bw() {
        for id in ModelId::paper_models() {
            let f = fig6_sim_vs_measured(id, 8);
            let sim = f.series("simulated (full util)").unwrap();
            let meas = f.series("measured-mode (Horovod-like)").unwrap();
            let gap1 = sim.y_at(1.0).unwrap() - meas.y_at(1.0).unwrap();
            let gap100 = sim.y_at(100.0).unwrap() - meas.y_at(100.0).unwrap();
            assert!(gap1 < 0.12, "{id}: gap at 1G = {gap1}");
            assert!(gap100 > 0.1, "{id}: gap at 100G = {gap100}");
            assert!(sim.y_at(100.0).unwrap() > 0.95, "{id}");
        }
    }

    #[test]
    fn fig7_simulated_near_one_even_at_64() {
        let f = fig7_simulated_at_100g();
        for id in ModelId::paper_models() {
            let s = f.series(&format!("{} simulated", id.name())).unwrap();
            assert!(s.y_at(64.0).unwrap() > 0.95, "{id}");
        }
    }

    #[test]
    fn fig8_10g_vs_100g() {
        let f10 = fig8_compression(10.0);
        let f100 = fig8_compression(100.0);
        // VGG16 at 10 Gbps: 10× compression → near-linear (paper's claim).
        let vgg10 = f10.series("VGG16").unwrap();
        assert!(vgg10.y_at(10.0).unwrap() > 0.9);
        // Diminishing: 100× adds little over 10×.
        assert!(vgg10.y_at(100.0).unwrap() - vgg10.y_at(10.0).unwrap() < 0.08);
        // At 100 Gbps compression is unnecessary (already near 1 at ratio 1).
        for s in &f100.series {
            assert!(s.y_at(1.0).unwrap() > 0.9, "{}", s.name);
        }
        // 2×–5× already recovers most of the gap at 10 Gbps.
        let rn50 = f10.series("ResNet50").unwrap();
        assert!(rn50.y_at(5.0).unwrap() > 0.9, "{}", rn50.y_at(5.0).unwrap());
    }
}
