//! Cluster topology: servers × GPUs-per-server worker addressing, ring
//! orders for all-reduce, the intra-node (NVLink) vs inter-node
//! (network) distinction the p3dn testbed has, and the two-tier
//! [`Cluster`] description the hierarchical (leader-ring) collective is
//! parameterized by.

use crate::Result;
use std::fmt;

/// Global worker (GPU) rank, `0..workers()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkerId(pub usize);

/// Server (instance) index, `0..servers`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServerId(pub usize);

impl fmt::Display for WorkerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "w{}", self.0)
    }
}

impl fmt::Display for ServerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Whether a worker-to-worker link crosses the network or stays on NVLink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Same server: NVLink-class, effectively not the bottleneck.
    IntraNode,
    /// Crosses servers: the provisioned network (the paper's subject).
    InterNode,
}

/// A `servers × gpus_per_server` cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub servers: usize,
    pub gpus_per_server: usize,
}

impl Topology {
    pub fn new(servers: usize, gpus_per_server: usize) -> Topology {
        assert!(servers >= 1 && gpus_per_server >= 1);
        Topology { servers, gpus_per_server }
    }

    /// Total workers.
    pub fn workers(&self) -> usize {
        self.servers * self.gpus_per_server
    }

    /// Server hosting a worker. Workers are numbered server-major:
    /// server 0 gets ranks `0..g`, server 1 gets `g..2g`, …
    pub fn server_of(&self, w: WorkerId) -> ServerId {
        assert!(w.0 < self.workers(), "worker {w} out of range");
        ServerId(w.0 / self.gpus_per_server)
    }

    /// Local (on-server) index of a worker.
    pub fn local_rank(&self, w: WorkerId) -> usize {
        assert!(w.0 < self.workers());
        w.0 % self.gpus_per_server
    }

    /// The designated leader worker (local rank 0) for a server.
    pub fn leader_of(&self, s: ServerId) -> WorkerId {
        assert!(s.0 < self.servers);
        WorkerId(s.0 * self.gpus_per_server)
    }

    /// All workers on a server.
    pub fn workers_on(&self, s: ServerId) -> Vec<WorkerId> {
        let base = s.0 * self.gpus_per_server;
        (base..base + self.gpus_per_server).map(WorkerId).collect()
    }

    /// Classify the link between two workers.
    pub fn link_class(&self, a: WorkerId, b: WorkerId) -> LinkClass {
        if self.server_of(a) == self.server_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Flat ring over all workers (rank order). Successor of the last is
    /// the first. This is the single-level ring NCCL uses when every hop
    /// cost is uniform; with `gpus_per_server > 1` most hops stay on
    /// NVLink and exactly `servers` hops cross the network.
    pub fn flat_ring(&self) -> Ring {
        Ring { order: (0..self.workers()).map(WorkerId).collect() }
    }

    /// Ring over server leaders only — the inter-node stage of a
    /// hierarchical all-reduce.
    pub fn leader_ring(&self) -> Ring {
        Ring { order: (0..self.servers).map(|s| self.leader_of(ServerId(s))).collect() }
    }

    /// Number of ring hops that cross the network in the flat ring.
    pub fn inter_node_hops(&self) -> usize {
        if self.servers == 1 {
            0
        } else {
            self.servers
        }
    }
}

/// An ordered ring of workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ring {
    order: Vec<WorkerId>,
}

impl Ring {
    pub fn new(order: Vec<WorkerId>) -> Ring {
        assert!(!order.is_empty());
        Ring { order }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn members(&self) -> &[WorkerId] {
        &self.order
    }

    /// Position of a worker in the ring.
    pub fn position(&self, w: WorkerId) -> Option<usize> {
        self.order.iter().position(|x| *x == w)
    }

    /// Next worker clockwise from `w`.
    pub fn next(&self, w: WorkerId) -> WorkerId {
        let i = self.position(w).expect("worker not in ring");
        self.order[(i + 1) % self.order.len()]
    }

    /// Previous worker (counter-clockwise) from `w`.
    pub fn prev(&self, w: WorkerId) -> WorkerId {
        let i = self.position(w).expect("worker not in ring");
        self.order[(i + self.order.len() - 1) % self.order.len()]
    }
}

/// A two-tier cluster for hierarchical collectives: `workers` ranks
/// partitioned into consecutive **groups** of (at most) `group_size`,
/// with a fast intra-group tier (NVLink / intra-rack) and a potentially
/// oversubscribed inter-group tier (the aggregation/core network).
///
/// The grouping rule is rank-major, mirroring [`Topology::server_of`]:
/// group `g` holds ranks `g·group_size .. min((g+1)·group_size, workers)`,
/// so the last group may be smaller when `group_size` does not divide
/// `workers` — the hierarchical collective handles ragged groups.
/// Rank `g·group_size` is group `g`'s **leader**.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cluster {
    /// Total ranks.
    pub workers: usize,
    /// Maximum ranks per group (the last group may be smaller).
    pub group_size: usize,
    /// Intra-group link bandwidth, Gbps (NVLink-class: fast).
    pub intra_gbps: f64,
    /// Provisioned per-leader uplink into the inter-group tier, Gbps.
    pub inter_gbps: f64,
    /// Oversubscription of the inter-group tier: 1 = full bisection,
    /// 4 = a 1:4 oversubscribed aggregation layer. Divides the bandwidth
    /// each concurrent inter-group flow actually sees.
    pub oversubscription: f64,
}

impl Cluster {
    /// Grouping-only constructor with the p3dn-flavored tier defaults
    /// (300 Gbps NVLink-class intra tier, 100 Gbps uplinks, full
    /// bisection). The wire algorithm in
    /// [`crate::collectives::hierarchical`] only reads the grouping.
    pub fn new(workers: usize, group_size: usize) -> Cluster {
        Cluster {
            workers,
            group_size,
            intra_gbps: 300.0,
            inter_gbps: 100.0,
            oversubscription: 1.0,
        }
    }

    /// Full constructor: grouping plus per-tier bandwidths and
    /// inter-tier oversubscription (the analytic model's knobs).
    pub fn with_tiers(
        workers: usize,
        group_size: usize,
        intra_gbps: f64,
        inter_gbps: f64,
        oversubscription: f64,
    ) -> Cluster {
        Cluster { workers, group_size, intra_gbps, inter_gbps, oversubscription }
    }

    /// Reject degenerate shapes.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.workers >= 1, "cluster needs >= 1 worker");
        anyhow::ensure!(self.group_size >= 1, "group size must be >= 1");
        anyhow::ensure!(
            self.intra_gbps > 0.0 && self.intra_gbps.is_finite(),
            "intra-tier bandwidth must be finite and > 0, got {}",
            self.intra_gbps
        );
        anyhow::ensure!(
            self.inter_gbps > 0.0 && self.inter_gbps.is_finite(),
            "inter-tier bandwidth must be finite and > 0, got {}",
            self.inter_gbps
        );
        anyhow::ensure!(
            self.oversubscription >= 1.0 && self.oversubscription.is_finite(),
            "oversubscription must be finite and >= 1, got {}",
            self.oversubscription
        );
        Ok(())
    }

    /// Number of groups (the last may be ragged).
    pub fn n_groups(&self) -> usize {
        self.workers.div_ceil(self.group_size)
    }

    /// Group index of a rank.
    pub fn group_of(&self, w: WorkerId) -> usize {
        assert!(w.0 < self.workers, "worker {w} out of range");
        w.0 / self.group_size
    }

    /// Ranks of one group, in ring order.
    pub fn members_of(&self, g: usize) -> Vec<WorkerId> {
        assert!(g < self.n_groups(), "group {g} out of range");
        let base = g * self.group_size;
        let end = (base + self.group_size).min(self.workers);
        (base..end).map(WorkerId).collect()
    }

    /// The leader (lowest rank) of a group.
    pub fn group_leader(&self, g: usize) -> WorkerId {
        assert!(g < self.n_groups(), "group {g} out of range");
        WorkerId(g * self.group_size)
    }

    /// Whether a rank leads its group.
    pub fn is_leader(&self, w: WorkerId) -> bool {
        assert!(w.0 < self.workers, "worker {w} out of range");
        w.0 % self.group_size == 0
    }

    /// Ring over one group's members (the intra tier of the hierarchy).
    pub fn group_ring(&self, g: usize) -> Ring {
        Ring::new(self.members_of(g))
    }

    /// Ring over the group leaders (the inter tier of the hierarchy).
    pub fn leader_ring(&self) -> Ring {
        Ring::new((0..self.n_groups()).map(|g| self.group_leader(g)).collect())
    }

    /// Per-flow bandwidth an inter-group transfer actually sees once the
    /// oversubscribed tier is shared: `inter_gbps / oversubscription`.
    pub fn effective_inter_gbps(&self) -> f64 {
        self.inter_gbps / self.oversubscription
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p3dn_shape() {
        let t = Topology::new(8, 8);
        assert_eq!(t.workers(), 64);
        assert_eq!(t.server_of(WorkerId(0)), ServerId(0));
        assert_eq!(t.server_of(WorkerId(63)), ServerId(7));
        assert_eq!(t.local_rank(WorkerId(17)), 1);
        assert_eq!(t.leader_of(ServerId(3)), WorkerId(24));
    }

    #[test]
    fn link_classification() {
        let t = Topology::new(2, 8);
        assert_eq!(t.link_class(WorkerId(0), WorkerId(7)), LinkClass::IntraNode);
        assert_eq!(t.link_class(WorkerId(7), WorkerId(8)), LinkClass::InterNode);
    }

    #[test]
    fn flat_ring_neighbors_wrap() {
        let t = Topology::new(2, 2);
        let r = t.flat_ring();
        assert_eq!(r.next(WorkerId(3)), WorkerId(0));
        assert_eq!(r.prev(WorkerId(0)), WorkerId(3));
    }

    #[test]
    fn flat_ring_crosses_network_servers_times() {
        let t = Topology::new(4, 8);
        let r = t.flat_ring();
        let crossings = r
            .members()
            .iter()
            .filter(|w| t.link_class(**w, r.next(**w)) == LinkClass::InterNode)
            .count();
        assert_eq!(crossings, 4);
        assert_eq!(t.inter_node_hops(), 4);
    }

    #[test]
    fn leader_ring_members() {
        let t = Topology::new(4, 8);
        let r = t.leader_ring();
        assert_eq!(r.members(), &[WorkerId(0), WorkerId(8), WorkerId(16), WorkerId(24)]);
    }

    #[test]
    fn single_server_has_no_network_hops() {
        let t = Topology::new(1, 8);
        assert_eq!(t.inter_node_hops(), 0);
    }

    #[test]
    fn workers_on_server() {
        let t = Topology::new(2, 4);
        assert_eq!(t.workers_on(ServerId(1)), vec![WorkerId(4), WorkerId(5), WorkerId(6), WorkerId(7)]);
    }

    #[test]
    fn cluster_even_groups() {
        let c = Cluster::new(16, 4);
        c.validate().unwrap();
        assert_eq!(c.n_groups(), 4);
        assert_eq!(c.group_of(WorkerId(7)), 1);
        assert_eq!(c.group_leader(2), WorkerId(8));
        assert!(c.is_leader(WorkerId(12)));
        assert!(!c.is_leader(WorkerId(13)));
        assert_eq!(c.members_of(3), vec![WorkerId(12), WorkerId(13), WorkerId(14), WorkerId(15)]);
        assert_eq!(
            c.leader_ring().members(),
            &[WorkerId(0), WorkerId(4), WorkerId(8), WorkerId(12)]
        );
    }

    #[test]
    fn cluster_ragged_last_group() {
        // 7 workers in groups of 3: groups {0,1,2}, {3,4,5}, {6}.
        let c = Cluster::new(7, 3);
        assert_eq!(c.n_groups(), 3);
        assert_eq!(c.members_of(2), vec![WorkerId(6)]);
        assert_eq!(c.group_of(WorkerId(6)), 2);
        assert!(c.is_leader(WorkerId(6)));
        assert_eq!(c.group_ring(2).len(), 1);
    }

    #[test]
    fn cluster_degenerate_shapes() {
        // group_size >= workers collapses to one group; group_size 1 makes
        // everyone a leader.
        let one_group = Cluster::new(4, 8);
        assert_eq!(one_group.n_groups(), 1);
        assert_eq!(one_group.members_of(0).len(), 4);
        let all_leaders = Cluster::new(4, 1);
        assert_eq!(all_leaders.n_groups(), 4);
        for w in 0..4 {
            assert!(all_leaders.is_leader(WorkerId(w)));
        }
        assert!(Cluster::new(0, 1).validate().is_err());
        assert!(Cluster::new(4, 0).validate().is_err());
        assert!(Cluster::with_tiers(4, 2, 100.0, 25.0, 0.5).validate().is_err());
    }

    #[test]
    fn cluster_effective_inter_rate() {
        let c = Cluster::with_tiers(16, 4, 300.0, 100.0, 4.0);
        assert_eq!(c.effective_inter_gbps(), 25.0);
    }

    #[test]
    fn cluster_groups_partition_all_workers() {
        for (workers, gs) in [(16usize, 4usize), (7, 3), (5, 5), (9, 2), (1, 1)] {
            let c = Cluster::new(workers, gs);
            let mut seen = Vec::new();
            for g in 0..c.n_groups() {
                let members = c.members_of(g);
                assert_eq!(members[0], c.group_leader(g));
                for m in &members {
                    assert_eq!(c.group_of(*m), g);
                }
                seen.extend(members);
            }
            assert_eq!(seen, (0..workers).map(WorkerId).collect::<Vec<_>>());
        }
    }
}
