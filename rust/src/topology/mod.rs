//! Cluster topology: servers × GPUs-per-server worker addressing, ring
//! orders for all-reduce, and the intra-node (NVLink) vs inter-node
//! (network) distinction the p3dn testbed has.

use std::fmt;

/// Global worker (GPU) rank, `0..workers()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkerId(pub usize);

/// Server (instance) index, `0..servers`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ServerId(pub usize);

impl fmt::Display for WorkerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "w{}", self.0)
    }
}

impl fmt::Display for ServerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "s{}", self.0)
    }
}

/// Whether a worker-to-worker link crosses the network or stays on NVLink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkClass {
    /// Same server: NVLink-class, effectively not the bottleneck.
    IntraNode,
    /// Crosses servers: the provisioned network (the paper's subject).
    InterNode,
}

/// A `servers × gpus_per_server` cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub servers: usize,
    pub gpus_per_server: usize,
}

impl Topology {
    pub fn new(servers: usize, gpus_per_server: usize) -> Topology {
        assert!(servers >= 1 && gpus_per_server >= 1);
        Topology { servers, gpus_per_server }
    }

    /// Total workers.
    pub fn workers(&self) -> usize {
        self.servers * self.gpus_per_server
    }

    /// Server hosting a worker. Workers are numbered server-major:
    /// server 0 gets ranks `0..g`, server 1 gets `g..2g`, …
    pub fn server_of(&self, w: WorkerId) -> ServerId {
        assert!(w.0 < self.workers(), "worker {w} out of range");
        ServerId(w.0 / self.gpus_per_server)
    }

    /// Local (on-server) index of a worker.
    pub fn local_rank(&self, w: WorkerId) -> usize {
        assert!(w.0 < self.workers());
        w.0 % self.gpus_per_server
    }

    /// The designated leader worker (local rank 0) for a server.
    pub fn leader_of(&self, s: ServerId) -> WorkerId {
        assert!(s.0 < self.servers);
        WorkerId(s.0 * self.gpus_per_server)
    }

    /// All workers on a server.
    pub fn workers_on(&self, s: ServerId) -> Vec<WorkerId> {
        let base = s.0 * self.gpus_per_server;
        (base..base + self.gpus_per_server).map(WorkerId).collect()
    }

    /// Classify the link between two workers.
    pub fn link_class(&self, a: WorkerId, b: WorkerId) -> LinkClass {
        if self.server_of(a) == self.server_of(b) {
            LinkClass::IntraNode
        } else {
            LinkClass::InterNode
        }
    }

    /// Flat ring over all workers (rank order). Successor of the last is
    /// the first. This is the single-level ring NCCL uses when every hop
    /// cost is uniform; with `gpus_per_server > 1` most hops stay on
    /// NVLink and exactly `servers` hops cross the network.
    pub fn flat_ring(&self) -> Ring {
        Ring { order: (0..self.workers()).map(WorkerId).collect() }
    }

    /// Ring over server leaders only — the inter-node stage of a
    /// hierarchical all-reduce.
    pub fn leader_ring(&self) -> Ring {
        Ring { order: (0..self.servers).map(|s| self.leader_of(ServerId(s))).collect() }
    }

    /// Number of ring hops that cross the network in the flat ring.
    pub fn inter_node_hops(&self) -> usize {
        if self.servers == 1 {
            0
        } else {
            self.servers
        }
    }
}

/// An ordered ring of workers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ring {
    order: Vec<WorkerId>,
}

impl Ring {
    pub fn new(order: Vec<WorkerId>) -> Ring {
        assert!(!order.is_empty());
        Ring { order }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn members(&self) -> &[WorkerId] {
        &self.order
    }

    /// Position of a worker in the ring.
    pub fn position(&self, w: WorkerId) -> Option<usize> {
        self.order.iter().position(|x| *x == w)
    }

    /// Next worker clockwise from `w`.
    pub fn next(&self, w: WorkerId) -> WorkerId {
        let i = self.position(w).expect("worker not in ring");
        self.order[(i + 1) % self.order.len()]
    }

    /// Previous worker (counter-clockwise) from `w`.
    pub fn prev(&self, w: WorkerId) -> WorkerId {
        let i = self.position(w).expect("worker not in ring");
        self.order[(i + self.order.len() - 1) % self.order.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p3dn_shape() {
        let t = Topology::new(8, 8);
        assert_eq!(t.workers(), 64);
        assert_eq!(t.server_of(WorkerId(0)), ServerId(0));
        assert_eq!(t.server_of(WorkerId(63)), ServerId(7));
        assert_eq!(t.local_rank(WorkerId(17)), 1);
        assert_eq!(t.leader_of(ServerId(3)), WorkerId(24));
    }

    #[test]
    fn link_classification() {
        let t = Topology::new(2, 8);
        assert_eq!(t.link_class(WorkerId(0), WorkerId(7)), LinkClass::IntraNode);
        assert_eq!(t.link_class(WorkerId(7), WorkerId(8)), LinkClass::InterNode);
    }

    #[test]
    fn flat_ring_neighbors_wrap() {
        let t = Topology::new(2, 2);
        let r = t.flat_ring();
        assert_eq!(r.next(WorkerId(3)), WorkerId(0));
        assert_eq!(r.prev(WorkerId(0)), WorkerId(3));
    }

    #[test]
    fn flat_ring_crosses_network_servers_times() {
        let t = Topology::new(4, 8);
        let r = t.flat_ring();
        let crossings = r
            .members()
            .iter()
            .filter(|w| t.link_class(**w, r.next(**w)) == LinkClass::InterNode)
            .count();
        assert_eq!(crossings, 4);
        assert_eq!(t.inter_node_hops(), 4);
    }

    #[test]
    fn leader_ring_members() {
        let t = Topology::new(4, 8);
        let r = t.leader_ring();
        assert_eq!(r.members(), &[WorkerId(0), WorkerId(8), WorkerId(16), WorkerId(24)]);
    }

    #[test]
    fn single_server_has_no_network_hops() {
        let t = Topology::new(1, 8);
        assert_eq!(t.inter_node_hops(), 0);
    }

    #[test]
    fn workers_on_server() {
        let t = Topology::new(2, 4);
        assert_eq!(t.workers_on(ServerId(1)), vec![WorkerId(4), WorkerId(5), WorkerId(6), WorkerId(7)]);
    }
}
