//! Elastic multi-process training with fault injection and recovery.
//!
//! `netbn launch` (see [`super::launch`]) drives a *fixed* cohort: the
//! world size is decided before rendezvous and a dead worker fails the
//! run. This module closes ROADMAP item 1's robustness half: membership
//! is **elastic** — workers join and leave at step boundaries, a killed
//! worker's shard is replayed from a checkpoint — and yet the final
//! parameter bits are provably identical to an uninterrupted run.
//!
//! The determinism scheme: the data-parallel work is split over a fixed
//! **logical shard count** `L` that never changes, only the assignment of
//! shards to live workers does. Shard `s`'s gradient stream is a private
//! RNG seeded from `(seed, s)`, advanced once per step — any worker can
//! (re)compute shard `s` at step `t` by fast-forwarding the stream, which
//! is how a crashed worker's shard is replayed. Each step every worker
//! computes its owned shards, all-gathers the raw per-shard gradient
//! blobs (tag [`crate::net::tags::SHARD_GATHER`]), and sums them **in
//! logical shard order `0..L`**. Floating-point addition is not
//! associative, but a fixed summation order makes the result independent
//! of which physical worker computed what — so an elastic run, a
//! fixed-membership run, and the single-process oracle
//! ([`expected_params`]) all produce the same bits, FNV-checkable with
//! [`super::launch::tensor_checksum`].
//!
//! Failure handling: every collective recv carries a deadline
//! ([`crate::net::mesh::MeshEndpoint::set_recv_timeout`]), so a dead peer
//! surfaces as an error naming the absent rank instead of a wedge.
//! Survivors poison their mailbox, abort to the coordinator, and rejoin;
//! the coordinator forms a new membership **epoch** — re-sharding over
//! the survivors, rolling laggards forward from the max-step survivor's
//! checkpoint — and the run completes. With recovery disabled the first
//! death fails the launch fast, naming the dead worker.

use super::launch::{tensor_checksum, SpawnMode};
use crate::net::mesh::MeshNode;
use crate::net::tcp::connect_retry;
use crate::net::{tag, tags, Endpoint};
use crate::topology::WorkerId;
use crate::tune::{straggler_scores, FeedbackRing, StepFeedback, StragglerScore};
use crate::util::Rng;
use crate::Result;
use anyhow::Context;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Shared experiment shape — identical on every participant.
#[derive(Clone, Debug)]
pub struct ElasticParams {
    /// Fixed logical shard count `L` (the data-parallel width that never
    /// changes; physical workers own contiguous shard ranges).
    pub shards: usize,
    /// Parameter/gradient tensor length (f32 elements).
    pub elems: usize,
    /// Total training steps.
    pub steps: usize,
    pub seed: u64,
    /// Modeled compute per step, microseconds (plus any injected skew).
    pub compute_us: u64,
    /// Bound on rendezvous and on each membership-epoch formation.
    pub rendezvous_timeout: Duration,
    /// Straggler scoring window (newest steps per worker).
    pub straggler_window: usize,
    /// Flag a worker whose mean compute exceeds `threshold x` the cohort
    /// median (see [`crate::tune::straggler_scores`]).
    pub straggler_threshold: f64,
    /// Record obs spans on every worker and ship them to the coordinator
    /// (also implied by [`ElasticConfig::trace_out`]).
    pub obs: bool,
}

impl Default for ElasticParams {
    fn default() -> Self {
        ElasticParams {
            shards: 8,
            elems: 4096,
            steps: 6,
            seed: 0xe1a5,
            compute_us: 0,
            rendezvous_timeout: Duration::from_secs(60),
            straggler_window: 8,
            straggler_threshold: 2.0,
            obs: false,
        }
    }
}

/// Scheduled membership: which workers exist, and when they enter or
/// leave the cohort (always at a step boundary).
#[derive(Clone, Debug, Default)]
pub struct MembershipPlan {
    /// Worker uids active from step 0.
    pub initial: Vec<u64>,
    /// `(uid, step)`: uid starts participating at `step`.
    pub joins: Vec<(u64, usize)>,
    /// `(uid, step)`: uid stops participating at `step`.
    pub leaves: Vec<(u64, usize)>,
}

impl MembershipPlan {
    /// Every uid the plan ever references (spawn set).
    pub fn all_uids(&self) -> Vec<u64> {
        let mut set: BTreeSet<u64> = self.initial.iter().copied().collect();
        set.extend(self.joins.iter().map(|(u, _)| *u));
        set.into_iter().collect()
    }

    /// The cohort that should be training at step `at` (sorted by uid —
    /// the rank order of every epoch).
    pub fn active_at(&self, at: usize) -> BTreeSet<u64> {
        let mut set: BTreeSet<u64> = self.initial.iter().copied().collect();
        for (u, s) in &self.joins {
            if *s <= at {
                set.insert(*u);
            }
        }
        for (u, s) in &self.leaves {
            if *s <= at {
                set.remove(u);
            }
        }
        set
    }

    /// The next scheduled membership change strictly after `at`, capped
    /// at `steps` — the end of the epoch that starts at `at`.
    fn next_boundary(&self, at: usize, steps: usize) -> usize {
        self.joins
            .iter()
            .chain(self.leaves.iter())
            .map(|(_, s)| *s)
            .filter(|s| *s > at)
            .min()
            .unwrap_or(steps)
            .min(steps)
    }
}

/// Scripted faults the coordinator injects or expects.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// `(uid, step)`: the worker abruptly exits (socket drops, no
    /// goodbye) when it reaches `step` — a crash simulated in-process,
    /// works in thread and process mode.
    pub die: Option<(u64, usize)>,
    /// `(uid, step)`: the coordinator SIGKILLs the worker's real OS
    /// process once it reports reaching `step` (process mode only).
    pub kill: Option<(u64, usize)>,
    /// `(uid, extra_us)`: added per-step compute skew — the straggler.
    pub straggle: Vec<(u64, u64)>,
    /// Replay the dead worker's shards from a checkpoint and finish the
    /// run (`true`), or fail fast naming the dead worker (`false`).
    pub recovery: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { die: None, kill: None, straggle: Vec::new(), recovery: true }
    }
}

/// One elastic launch invocation.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    pub params: ElasticParams,
    pub plan: MembershipPlan,
    pub fault: FaultPlan,
    pub spawn: SpawnMode,
    /// Coordinator bind address (`127.0.0.1:0` for loopback runs; a
    /// routable interface for multi-host cohorts).
    pub bind: SocketAddr,
    /// Write the merged Chrome trace of the whole run here (implies span
    /// recording on every worker). Each epoch's rank 0 merges the
    /// cohort's spans and ships them to the coordinator over the
    /// feedback socket, so the file lands on the coordinator's
    /// filesystem even with external multi-host workers. Spans keep
    /// their recording clocks: uids are stable track ids, and tracks
    /// from distinct worker processes are only approximately aligned.
    pub trace_out: Option<std::path::PathBuf>,
}

impl ElasticConfig {
    pub fn loopback(params: ElasticParams, plan: MembershipPlan) -> ElasticConfig {
        ElasticConfig {
            params,
            plan,
            fault: FaultPlan::default(),
            spawn: SpawnMode::Thread,
            bind: "127.0.0.1:0".parse().expect("loopback literal"),
            trace_out: None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        let p = &self.params;
        anyhow::ensure!(p.shards >= 1, "elastic needs >= 1 logical shard");
        anyhow::ensure!(p.elems >= 1, "elastic needs >= 1 tensor element");
        anyhow::ensure!(p.steps >= 1, "elastic needs >= 1 step");
        anyhow::ensure!(
            p.rendezvous_timeout > Duration::ZERO,
            "rendezvous timeout must be > 0"
        );
        anyhow::ensure!(p.straggler_window >= 1, "straggler window must be >= 1");
        anyhow::ensure!(
            p.straggler_threshold.is_finite() && p.straggler_threshold > 1.0,
            "straggler threshold must be finite and > 1"
        );
        anyhow::ensure!(!self.plan.initial.is_empty(), "initial membership is empty");
        let mut seen = BTreeSet::new();
        for u in &self.plan.initial {
            anyhow::ensure!(seen.insert(*u), "uid {u} listed twice in initial membership");
        }
        for (u, s) in &self.plan.joins {
            anyhow::ensure!(seen.insert(*u), "joining uid {u} already a member");
            anyhow::ensure!(
                (1..p.steps).contains(s),
                "join step {s} for uid {u} must be inside the run (1..{})",
                p.steps
            );
        }
        let mut left = BTreeSet::new();
        for (u, s) in &self.plan.leaves {
            anyhow::ensure!(seen.contains(u), "leaving uid {u} is not a member");
            anyhow::ensure!(left.insert(*u), "uid {u} leaves twice");
            anyhow::ensure!(
                (1..p.steps).contains(s),
                "leave step {s} for uid {u} must be inside the run (1..{})",
                p.steps
            );
            if let Some((_, joined)) = self.plan.joins.iter().find(|(ju, _)| ju == u) {
                anyhow::ensure!(*s > *joined, "uid {u} leaves at {s} before joining");
            }
        }
        anyhow::ensure!(
            !self.plan.active_at(p.steps).is_empty(),
            "no member remains at the end of the schedule"
        );
        // Every epoch's world must be covered by the shard count, so no
        // rank ever owns zero shards.
        let max_world = (0..=p.steps)
            .map(|s| self.plan.active_at(s).len())
            .max()
            .unwrap_or(0);
        anyhow::ensure!(
            p.shards >= max_world,
            "{} logical shards cannot cover a cohort of {max_world}",
            p.shards
        );
        let member = |u: u64| seen.contains(&u);
        if let Some((u, s)) = self.fault.die {
            anyhow::ensure!(member(u), "die target uid {u} is not a member");
            anyhow::ensure!(s < p.steps, "die step {s} past the run");
        }
        if let Some((u, s)) = self.fault.kill {
            anyhow::ensure!(member(u), "kill target uid {u} is not a member");
            anyhow::ensure!(s < p.steps, "kill step {s} past the run");
            anyhow::ensure!(
                self.spawn == SpawnMode::Process,
                "SIGKILL injection needs real worker processes (--spawn process)"
            );
        }
        for (u, extra) in &self.fault.straggle {
            anyhow::ensure!(member(*u), "straggle target uid {u} is not a member");
            anyhow::ensure!(*extra > 0, "straggle extra_us must be > 0");
        }
        Ok(())
    }
}

/// What the coordinator learned from a finished elastic run.
#[derive(Clone, Debug)]
pub struct ElasticReport {
    /// The cohort-identical FNV-1a checksum of the final parameters.
    pub checksum: u64,
    pub steps: usize,
    /// Membership epochs formed (>= 1).
    pub epochs: usize,
    /// Worker deaths survived via checkpoint replay.
    pub recoveries: usize,
    /// Cohort size at the final step.
    pub final_world: usize,
    /// `(resume step, rank-ordered uids)` per epoch.
    pub membership: Vec<(usize, Vec<u64>)>,
    /// Per-worker straggler verdicts (sorted by uid).
    pub stragglers: Vec<StragglerScore>,
    /// Straggler-onset detections from the same feedback rings, in the
    /// wire format the serve daemon and `LaunchReport` use (see
    /// [`crate::obs::detect::straggler_onset`]).
    pub detections: Vec<crate::obs::Detection>,
}

// ------------------------------------------------------------ determinism

/// Contiguous shard range owned by `rank` of `world` over `shards`
/// logical shards (first `shards % world` ranks take one extra).
pub fn shard_range(rank: usize, world: usize, shards: usize) -> Range<usize> {
    assert!(rank < world, "rank {rank} out of world {world}");
    let base = shards / world;
    let rem = shards % world;
    let start = rank * base + rank.min(rem);
    let len = base + usize::from(rank < rem);
    start..start + len
}

/// Shard `s`'s private gradient stream — any worker reconstructs it from
/// the run seed alone (the replay property).
fn shard_rng(seed: u64, shard: usize) -> Rng {
    Rng::new(seed ^ 0xE1A5_71C0 ^ ((shard as u64) << 32))
}

/// Single-process oracle: the exact final parameters of an uninterrupted
/// run, summing shard gradients in logical order `0..L` — the bit
/// pattern every elastic run must reproduce.
pub fn expected_params(p: &ElasticParams) -> Vec<f32> {
    let mut streams: Vec<Rng> = (0..p.shards).map(|s| shard_rng(p.seed, s)).collect();
    let mut params = vec![0.0f32; p.elems];
    let mut g = vec![0.0f32; p.elems];
    let inv = 1.0f32 / p.shards as f32;
    for _ in 0..p.steps {
        let mut acc = vec![0.0f32; p.elems];
        for stream in streams.iter_mut() {
            stream.fill_f32(&mut g, 1.0);
            for (a, x) in acc.iter_mut().zip(&g) {
                *a += *x;
            }
        }
        for (w, a) in params.iter_mut().zip(&acc) {
            *w -= 0.05 * a * inv;
        }
    }
    params
}

/// FNV checksum of [`expected_params`] — the oracle the scenarios and
/// the fault suite compare elastic runs against.
pub fn expected_checksum(p: &ElasticParams) -> u64 {
    tensor_checksum(&expected_params(p))
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(b.len() % 4 == 0, "f32 blob length {} not a multiple of 4", b.len());
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ------------------------------------------------------------ worker side

/// How long a worker waits on a peer's shard blob before declaring it
/// dead: generous against scheduler noise, small against the rendezvous
/// timeout so a no-recovery failure is visibly "fast".
fn recv_deadline(compute_us: u64) -> Duration {
    Duration::from_millis(2_000) + Duration::from_micros(50 * compute_us)
}

/// One elastic worker's whole life: join, serve membership epochs until
/// the coordinator says goodbye. `die_at` simulates a crash — reaching
/// that global step the worker drops its sockets and exits without a
/// word. This is what `netbn _eworker` calls.
pub fn elastic_worker_entry(
    uid: u64,
    coordinator: SocketAddr,
    die_at: Option<usize>,
) -> Result<()> {
    let coord = connect_retry(coordinator, Duration::from_secs(10))
        .context("connect to elastic coordinator")?;
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let bind_ip = coord.local_addr()?.ip();
    let mut writer = coord.try_clone()?;
    let mut reader = BufReader::new(coord);
    let pid = std::process::id();
    writeln!(writer, "ejoin {uid} {pid} 0").context("send ejoin")?;

    let mut params: Vec<f32> = Vec::new();
    // Pending prep: (epoch, rank, world, extra_us, bound node).
    let mut prep: Option<(usize, usize, usize, u64, MeshNode)> = None;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("read coordinator line")?;
        anyhow::ensure!(n > 0, "coordinator closed the connection");
        let mut it = line.split_whitespace();
        match it.next() {
            Some("eprep") => {
                let epoch: usize = parse_field(it.next(), "eprep epoch")?;
                let rank: usize = parse_field(it.next(), "eprep rank")?;
                let world: usize = parse_field(it.next(), "eprep world")?;
                let extra_us: u64 = parse_field(it.next(), "eprep extra_us")?;
                // Fresh node per epoch: the old peer table (and any
                // half-dead connections) is torn down wholesale.
                let node = MeshNode::bind_on(bind_ip, WorkerId(rank), world)?;
                writeln!(writer, "eaddr {uid} {}", node.addr()).context("send eaddr")?;
                prep = Some((epoch, rank, world, extra_us, node));
            }
            Some("epoch") => {
                let (_epoch, rank, world, extra_us, node) =
                    prep.take().context("epoch line without a preceding eprep")?;
                let resume: usize = parse_field(it.next(), "epoch resume")?;
                let until: usize = parse_field(it.next(), "epoch until")?;
                let steps: usize = parse_field(it.next(), "epoch steps")?;
                let shards: usize = parse_field(it.next(), "epoch shards")?;
                let elems: usize = parse_field(it.next(), "epoch elems")?;
                let seed: u64 = parse_field(it.next(), "epoch seed")?;
                let compute_us: u64 = parse_field(it.next(), "epoch compute_us")?;
                let obs = parse_field::<u8>(it.next(), "epoch obs")? != 0;
                let wire_world: usize = parse_field(it.next(), "epoch world")?;
                anyhow::ensure!(
                    wire_world == world,
                    "epoch world {wire_world} disagrees with prepped world {world}"
                );
                let addrs: Vec<SocketAddr> = (0..world)
                    .map(|_| parse_field(it.next(), "epoch peer address"))
                    .collect::<Result<_>>()?;
                let plen: usize = parse_field(it.next(), "epoch checkpoint length")?;
                if plen > 0 {
                    let mut blob = vec![0u8; plen];
                    reader.read_exact(&mut blob).context("read checkpoint blob")?;
                    params = bytes_to_f32s(&blob)?;
                    anyhow::ensure!(params.len() == elems, "checkpoint length mismatch");
                } else if params.is_empty() {
                    params = vec![0.0f32; elems];
                }
                if obs {
                    crate::obs::span::enable();
                }
                let seg = run_segment(
                    &mut params,
                    SegmentSpec {
                        rank,
                        world,
                        shards,
                        elems,
                        seed,
                        resume,
                        until,
                        compute_us: compute_us + extra_us,
                        die_at,
                        addrs,
                        node,
                        uid,
                        obs,
                        feedback: writer.try_clone()?,
                    },
                );
                match seg {
                    Ok(SegmentEnd::Died) => return Ok(()),
                    Ok(SegmentEnd::Completed) => {
                        if until == steps {
                            let checksum = tensor_checksum(&params);
                            writeln!(writer, "edone {uid} {checksum:x}")
                                .context("send edone")?;
                        } else {
                            writeln!(writer, "ejoin {uid} {pid} {until}")
                                .context("send ejoin")?;
                        }
                    }
                    Err(e) => {
                        // The failed epoch's progress is discarded; the
                        // coordinator rolls us forward from a checkpoint.
                        let reason = flatten_reason(&e);
                        writeln!(writer, "eabort {uid} {resume} {reason}")
                            .context("send eabort")?;
                    }
                }
            }
            Some("eparams?") => {
                let blob = crate::collectives::f32s_as_bytes(&params).to_vec();
                writeln!(writer, "eparams {}", blob.len()).context("send eparams header")?;
                writer.write_all(&blob).context("send eparams blob")?;
            }
            Some("ebye") => return Ok(()),
            Some("efail") => {
                let reason: String = it.collect::<Vec<_>>().join(" ");
                anyhow::bail!("coordinator failed the launch: {reason}");
            }
            other => anyhow::bail!("unexpected coordinator line {other:?}"),
        }
    }
}

fn parse_field<T: std::str::FromStr>(field: Option<&str>, what: &str) -> Result<T> {
    field
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("bad or missing field: {what}"))
}

fn flatten_reason(e: &anyhow::Error) -> String {
    format!("{e:#}").replace('\n', " ")
}

enum SegmentEnd {
    /// Ran every step in `resume..until`.
    Completed,
    /// Simulated crash: exit without a word.
    Died,
}

struct SegmentSpec {
    rank: usize,
    world: usize,
    shards: usize,
    elems: usize,
    seed: u64,
    resume: usize,
    until: usize,
    compute_us: u64,
    die_at: Option<usize>,
    addrs: Vec<SocketAddr>,
    node: MeshNode,
    uid: u64,
    /// Record spans and ship them to the epoch's rank 0 every step.
    obs: bool,
    /// Coordinator stream for live `estep` heartbeats.
    feedback: TcpStream,
}

/// Sub-tag on [`tags::CONTROL`] carrying span snapshots (the shard
/// all-gather rides [`tags::SHARD_GATHER`] sub 0, so the two flows
/// never collide). Mirrors [`super::launch`]'s obs shipping.
const OBS_SUB: u32 = 1;

/// One obs shipping round at a step boundary: the worker drains the
/// spans it recorded since the previous round (uid-filtered —
/// thread-mode cohorts share one process-global ring) and sends them to
/// the epoch's rank 0, which merges the batches with its own.
fn ship_segment_spans(
    ep: &dyn Endpoint,
    rank: usize,
    world: usize,
    uid: u64,
    step: u32,
    cursor: &mut u64,
    merged: &mut Vec<crate::obs::SpanRecord>,
) -> Result<()> {
    use crate::obs::span;
    let ctrl = tag(tags::CONTROL, step, OBS_SUB);
    let (batch, next) = span::since(*cursor, Some(uid as u32));
    *cursor = next;
    if rank == 0 {
        merged.extend(batch);
        for peer in 1..world {
            let raw = ep.recv_buf(WorkerId(peer), ctrl)?;
            merged.extend(span::decode(&raw)?);
        }
    } else {
        ep.send(WorkerId(0), ctrl, &span::encode(&batch))?;
    }
    Ok(())
}

/// Run one epoch's steps `resume..until` of the elastic loop over a
/// fresh mesh. The epoch is all-or-nothing: updates accumulate on a
/// working copy and `params` is only overwritten after every step
/// completed — on error the caller's parameters are untouched, which is
/// what makes the coordinator's checkpoint/rollback sound.
fn run_segment(params: &mut Vec<f32>, spec: SegmentSpec) -> Result<SegmentEnd> {
    let SegmentSpec {
        rank,
        world,
        shards,
        elems,
        seed,
        resume,
        until,
        compute_us,
        die_at,
        addrs,
        node,
        uid,
        obs,
        mut feedback,
    } = spec;
    let own = shard_range(rank, world, shards);
    // Spans recorded before this segment belong to earlier epochs and
    // were already shipped there — start the drain cursor at "now".
    let mut obs_cursor = crate::obs::span::cursor();
    let mut obs_merged: Vec<crate::obs::SpanRecord> = Vec::new();
    // Fast-forward the owned shard streams to `resume` by replaying
    // their fills — the crash-replay mechanism.
    let mut scratch = vec![0.0f32; elems];
    let mut streams: Vec<Rng> = own
        .clone()
        .map(|s| {
            let mut r = shard_rng(seed, s);
            for _ in 0..resume {
                r.fill_f32(&mut scratch, 1.0);
            }
            r
        })
        .collect();
    let ep = node.connect(addrs)?;
    ep.set_recv_timeout(Some(recv_deadline(compute_us)));
    let compute_s = compute_us as f64 * 1e-6;
    let mut working = params.clone();
    let result = (|| -> Result<SegmentEnd> {
        for step in resume..until {
            if die_at == Some(step) {
                ep.poison("simulated crash");
                return Ok(SegmentEnd::Died);
            }
            let t_step = Instant::now();
            // Spans use the run-stable uid as the track id, not the
            // epoch rank: ranks are re-dealt every epoch and thread-mode
            // cohorts share one process-global ring.
            let total_sp = crate::span!("step.total", uid, step);
            // Own shards: fill from the per-shard streams, modeled
            // compute, then one concatenated blob for the all-gather.
            let mut own_grads: Vec<Vec<f32>> = Vec::with_capacity(own.len());
            let compute_elapsed;
            {
                let _sp =
                    crate::span!("step.grad", uid, step, (own.len() * elems * 4) as u64);
                for stream in streams.iter_mut() {
                    let mut g = vec![0.0f32; elems];
                    stream.fill_f32(&mut g, 1.0);
                    own_grads.push(g);
                }
                let t_compute = Instant::now();
                if compute_s > 0.0 {
                    super::spin_sleep(compute_s);
                }
                compute_elapsed = t_compute.elapsed().as_secs_f64();
            }
            let mut blob = Vec::with_capacity(own.len() * elems * 4);
            for g in &own_grads {
                blob.extend_from_slice(crate::collectives::f32s_as_bytes(g));
            }
            let t = tag(tags::SHARD_GATHER, step as u32, 0);
            {
                let _sp = crate::span!(
                    "wire.send",
                    uid,
                    step,
                    (blob.len() * world.saturating_sub(1)) as u64
                );
                for peer in 0..world {
                    if peer != rank {
                        ep.send(WorkerId(peer), t, &blob)?;
                    }
                }
            }
            let mut peer_blobs: Vec<Option<Vec<f32>>> = (0..world).map(|_| None).collect();
            for peer in 0..world {
                if peer != rank {
                    // Pooled frame: decoded then recycled, no detach.
                    let raw = ep.recv_buf(WorkerId(peer), t).map_err(|e| {
                        ep.poison(format!("step {step}: {e}"));
                        e.context(format!("all-gather at step {step}"))
                    })?;
                    peer_blobs[peer] = Some(bytes_to_f32s(&raw)?);
                }
            }
            // Sum in logical shard order 0..L — the bit-identity pivot.
            let reduce_sp = crate::span!("reduce.add", uid, step);
            let mut acc = vec![0.0f32; elems];
            for s in 0..shards {
                let owner = (0..world)
                    .find(|r| shard_range(*r, world, shards).contains(&s))
                    .expect("every shard has an owner");
                let range = shard_range(owner, world, shards);
                let idx = s - range.start;
                if owner == rank {
                    for (a, x) in acc.iter_mut().zip(&own_grads[idx]) {
                        *a += *x;
                    }
                } else {
                    let flat = peer_blobs[owner].as_ref().expect("received above");
                    anyhow::ensure!(
                        flat.len() == range.len() * elems,
                        "rank {owner} sent a blob of {} f32s, expected {}",
                        flat.len(),
                        range.len() * elems
                    );
                    let slice = &flat[idx * elems..(idx + 1) * elems];
                    for (a, x) in acc.iter_mut().zip(slice) {
                        *a += *x;
                    }
                }
            }
            drop(reduce_sp);
            let inv = 1.0f32 / shards as f32;
            {
                let _sp = crate::span!("step.update", uid, step);
                for (w, a) in working.iter_mut().zip(&acc) {
                    *w -= 0.05 * a * inv;
                }
            }
            drop(total_sp);
            writeln!(
                feedback,
                "estep {uid} {step} {:.9} {:.9}",
                t_step.elapsed().as_secs_f64(),
                compute_elapsed
            )
            .context("send estep heartbeat")?;
            // Obs shipping rides the same mesh after the step's gather
            // drained, so the control traffic never contends with
            // gradient blobs.
            if obs {
                ship_segment_spans(
                    &*ep, rank, world, uid, step as u32, &mut obs_cursor, &mut obs_merged,
                )?;
            }
        }
        // One last round sweeps anything recorded after the final
        // step's drain (every rank participates — rank 0 recvs).
        if obs {
            ship_segment_spans(
                &*ep, rank, world, uid, until as u32, &mut obs_cursor, &mut obs_merged,
            )?;
        }
        Ok(SegmentEnd::Completed)
    })();
    if matches!(result, Ok(SegmentEnd::Completed)) {
        *params = working;
        // The epoch's rank 0 forwards the cohort's merged spans to the
        // coordinator: header line then exact bytes, like `eparams`.
        if obs && rank == 0 && !obs_merged.is_empty() {
            let blob = crate::obs::span::encode(&obs_merged);
            writeln!(feedback, "espans {}", blob.len()).context("send espans header")?;
            feedback.write_all(&blob).context("send espans blob")?;
        }
    }
    result
}

// --------------------------------------------------------- coordinator side

enum Ev {
    Line(usize, String),
    Blob(usize, Vec<u8>),
    /// Encoded span snapshot from an epoch's rank 0 (`espans`).
    Spans(Vec<u8>),
    Eof(usize),
}

struct Member {
    conn: usize,
    writer: TcpStream,
    pid: u32,
    completed: usize,
    /// Has an unanswered (e)join/abort — ready for the next epoch.
    pending: bool,
    /// Released with `ebye` (a scheduled leaver or a finished worker);
    /// its EOF is expected, not a death.
    byed: bool,
    done: Option<u64>,
    ring: FeedbackRing,
    addr: Option<SocketAddr>,
}

struct PrepState {
    resume: usize,
    until: usize,
    ranks: Vec<u64>,
    need_blob: bool,
    blob: Option<Vec<u8>>,
    blob_from: Option<u64>,
}

/// Run a full elastic launch: bind the coordinator, bring up every
/// scheduled worker, serve membership epochs through joins, leaves,
/// crashes and recoveries, and aggregate the report.
pub fn elastic_launch(cfg: &ElasticConfig) -> Result<ElasticReport> {
    cfg.validate()?;
    crate::util::signal::install();
    let listener = TcpListener::bind(cfg.bind).context("bind elastic coordinator")?;
    let addr = listener.local_addr()?;
    let uids = cfg.plan.all_uids();
    let die_of = |u: u64| cfg.fault.die.and_then(|(du, ds)| (du == u).then_some(ds));
    let expected_dead: BTreeSet<u64> = cfg
        .fault
        .die
        .iter()
        .chain(cfg.fault.kill.iter())
        .map(|(u, _)| *u)
        .collect();

    match cfg.spawn {
        SpawnMode::Thread => {
            let mut handles = Vec::new();
            for &u in &uids {
                let die = die_of(u);
                handles.push((u, std::thread::spawn(move || elastic_worker_entry(u, addr, die))));
            }
            let report = coordinator_loop(&listener, cfg);
            for (u, h) in handles {
                let joined = h.join().map_err(|_| anyhow::anyhow!("worker {u} panicked"));
                if report.is_ok() {
                    joined?.with_context(|| format!("worker {u} failed"))?;
                }
            }
            report
        }
        SpawnMode::Process => {
            let exe = std::env::var_os("NETBN_WORKER_EXE")
                .map(std::path::PathBuf::from)
                .map_or_else(std::env::current_exe, Ok)
                .context("locate the netbn binary")?;
            let mut children = Vec::new();
            for &u in &uids {
                let mut cmd = std::process::Command::new(&exe);
                cmd.arg("_eworker")
                    .arg("--uid")
                    .arg(u.to_string())
                    .arg("--coordinator")
                    .arg(addr.to_string());
                if let Some(ds) = die_of(u) {
                    cmd.arg("--die-at").arg(ds.to_string());
                }
                let child =
                    cmd.spawn().with_context(|| format!("spawn elastic worker {u}"))?;
                children.push((u, child));
            }
            let report = coordinator_loop(&listener, cfg);
            if report.is_err() {
                for (_, c) in &mut children {
                    let _ = c.kill();
                }
            }
            for (u, mut c) in children {
                let status = c.wait().with_context(|| format!("wait for worker {u}"))?;
                if report.is_ok() && !expected_dead.contains(&u) {
                    anyhow::ensure!(status.success(), "worker {u} exited with {status}");
                }
            }
            report
        }
        SpawnMode::External => {
            // Workers are started by hand (`netbn _eworker --coordinator ...`).
            coordinator_loop(&listener, cfg)
        }
    }
}

fn coordinator_loop(listener: &TcpListener, cfg: &ElasticConfig) -> Result<ElasticReport> {
    let p = &cfg.params;
    listener.set_nonblocking(true).context("set elastic listener non-blocking")?;
    let (tx, rx) = mpsc::channel::<Ev>();
    let mut next_conn = 0usize;
    let mut conn_uid: HashMap<usize, u64> = HashMap::new();
    // Writer halves parked until the worker identifies itself via ejoin.
    let mut conn_writers: HashMap<usize, TcpStream> = HashMap::new();
    let mut members: BTreeMap<u64, Member> = BTreeMap::new();
    let mut dead: BTreeSet<u64> = BTreeSet::new();
    let mut killed = false;
    let mut epochs = 0usize;
    let mut recoveries = 0usize;
    let mut membership: Vec<(usize, Vec<u64>)> = Vec::new();
    let mut prep: Option<PrepState> = None;
    // Spans shipped by each completed epoch's rank 0, accumulated across
    // epochs (a failed epoch ships nothing — its spans die with it).
    let mut spans: Vec<crate::obs::SpanRecord> = Vec::new();
    let mut deadline = Instant::now() + p.rendezvous_timeout;

    let fail_all = |members: &mut BTreeMap<u64, Member>, why: &str| {
        for m in members.values_mut() {
            let _ = writeln!(m.writer, "efail {why}");
        }
    };
    let uid_rank = |membership: &[(usize, Vec<u64>)], uid: u64| -> String {
        membership
            .last()
            .and_then(|(_, ranks)| ranks.iter().position(|u| *u == uid))
            .map_or_else(|| "unranked".to_string(), |r| format!("rank {r}"))
    };

    loop {
        anyhow::ensure!(
            !crate::util::signal::triggered(),
            "interrupted (SIGINT/SIGTERM) during elastic launch"
        );
        anyhow::ensure!(
            Instant::now() < deadline,
            "elastic rendezvous timed out after {:?}: {} of {} scheduled workers joined, \
             waiting on epoch formation",
            p.rendezvous_timeout,
            members.len(),
            cfg.plan.all_uids().len()
        );
        // Admit new connections.
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                let conn = next_conn;
                next_conn += 1;
                let tx = tx.clone();
                let reader_stream = stream.try_clone()?;
                std::thread::spawn(move || reader_thread(conn, reader_stream, tx));
                // The writer half is claimed on the ejoin line.
                conn_writers.insert(conn, stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => return Err(e).context("accept elastic worker"),
        }
        // Drain one event (bounded wait keeps the accept loop live).
        let ev = match rx.recv_timeout(Duration::from_millis(25)) {
            Ok(ev) => ev,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                maybe_advance(
                    cfg, &mut members, &dead, &mut prep, &mut epochs, &mut membership,
                )?;
                if let Some(report) = maybe_finish(
                    cfg, &mut members, &dead, epochs, recoveries, &membership, &spans,
                )? {
                    return Ok(report);
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => unreachable!("tx held locally"),
        };
        deadline = Instant::now() + p.rendezvous_timeout;
        match ev {
            Ev::Line(conn, line) => {
                let mut it = line.split_whitespace();
                let verb = it.next().unwrap_or("");
                let uid: u64 = parse_field(it.next(), "worker uid")?;
                match verb {
                    "ejoin" => {
                        let pid: u32 = parse_field(it.next(), "ejoin pid")?;
                        let completed: usize = parse_field(it.next(), "ejoin completed")?;
                        anyhow::ensure!(
                            cfg.plan.all_uids().contains(&uid),
                            "unscheduled uid {uid} tried to join"
                        );
                        conn_uid.insert(conn, uid);
                        let writer = conn_writers
                            .remove(&conn)
                            .context("ejoin from an unknown connection")?;
                        let m = members.entry(uid).or_insert_with(|| Member {
                            conn,
                            writer,
                            pid,
                            completed: 0,
                            pending: false,
                            byed: false,
                            done: None,
                            ring: FeedbackRing::new(32),
                            addr: None,
                        });
                        m.conn = conn;
                        m.pid = pid;
                        m.completed = completed;
                        m.pending = true;
                    }
                    "eaddr" => {
                        let a: SocketAddr = parse_field(it.next(), "eaddr address")?;
                        if let Some(m) = members.get_mut(&uid) {
                            m.addr = Some(a);
                        }
                    }
                    "estep" => {
                        let step: usize = parse_field(it.next(), "estep step")?;
                        let wall: f64 = parse_field(it.next(), "estep wall")?;
                        let compute: f64 = parse_field(it.next(), "estep compute")?;
                        if let Some(m) = members.get_mut(&uid) {
                            m.ring.push(StepFeedback {
                                step: step as u64,
                                wall_s: wall,
                                compute_s: compute,
                                comm_busy_s: 0.0,
                                busbw_gbps: 0.0,
                            });
                        }
                        if let Some((ku, ks)) = cfg.fault.kill {
                            if !killed && ku == uid && step >= ks {
                                killed = true;
                                if let Some(m) = members.get(&uid) {
                                    crate::util::signal::kill_process(m.pid);
                                }
                            }
                        }
                    }
                    "eabort" => {
                        let completed: usize = parse_field(it.next(), "eabort completed")?;
                        let reason: String = it.collect::<Vec<_>>().join(" ");
                        if !cfg.fault.recovery {
                            fail_all(&mut members, &reason);
                            anyhow::bail!(
                                "worker {uid} ({}) aborted at step {completed}: {reason}",
                                uid_rank(&membership, uid)
                            );
                        }
                        if let Some(m) = members.get_mut(&uid) {
                            m.completed = completed;
                            m.pending = true;
                        }
                        prep = None; // restart any in-flight formation
                    }
                    "edone" => {
                        let checksum = it
                            .next()
                            .and_then(|s| u64::from_str_radix(s, 16).ok())
                            .context("edone without a checksum")?;
                        if let Some(m) = members.get_mut(&uid) {
                            m.done = Some(checksum);
                            m.pending = false;
                        }
                    }
                    other => anyhow::bail!("unexpected worker line {other:?} from {uid}"),
                }
            }
            Ev::Blob(conn, bytes) => {
                if let Some(uid) = conn_uid.get(&conn) {
                    if let Some(ps) = prep.as_mut() {
                        if ps.blob_from == Some(*uid) {
                            ps.blob = Some(bytes);
                        }
                    }
                }
            }
            Ev::Spans(bytes) => {
                spans.extend(
                    crate::obs::span::decode(&bytes).context("decode shipped span snapshot")?,
                );
            }
            Ev::Eof(conn) => {
                let Some(uid) = conn_uid.get(&conn).copied() else { continue };
                let Some(m) = members.get(&uid) else { continue };
                if m.conn != conn || m.byed || m.done.is_some() {
                    continue; // stale or expected disconnect
                }
                // A live member's socket dropped: a death.
                if !cfg.fault.recovery {
                    let why = format!(
                        "worker {uid} ({}) died after step {} (connection dropped)",
                        uid_rank(&membership, uid),
                        m.completed
                    );
                    fail_all(&mut members, &why);
                    anyhow::bail!("{why}");
                }
                dead.insert(uid);
                recoveries += 1;
                members.get_mut(&uid).expect("checked").pending = false;
                // Abort any formation that counted on the dead worker.
                if prep.as_ref().map_or(false, |ps| ps.ranks.contains(&uid)) {
                    prep = None;
                }
            }
        }
        maybe_advance(cfg, &mut members, &dead, &mut prep, &mut epochs, &mut membership)?;
        if let Some(report) =
            maybe_finish(cfg, &mut members, &dead, epochs, recoveries, &membership, &spans)?
        {
            return Ok(report);
        }
    }
}

fn reader_thread(conn: usize, stream: TcpStream, tx: mpsc::Sender<Ev>) {
    stream.set_read_timeout(None).ok();
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => {
                let _ = tx.send(Ev::Eof(conn));
                return;
            }
            Ok(_) => {}
        }
        let trimmed = line.trim().to_string();
        if let Some(rest) = trimmed.strip_prefix("eparams ") {
            // Binary checkpoint upload: header line then exact bytes.
            let Ok(len) = rest.trim().parse::<usize>() else {
                let _ = tx.send(Ev::Eof(conn));
                return;
            };
            let mut blob = vec![0u8; len];
            if reader.read_exact(&mut blob).is_err() {
                let _ = tx.send(Ev::Eof(conn));
                return;
            }
            let _ = tx.send(Ev::Blob(conn, blob));
        } else if let Some(rest) = trimmed.strip_prefix("espans ") {
            // Span snapshot upload: same header-then-bytes framing.
            let Ok(len) = rest.trim().parse::<usize>() else {
                let _ = tx.send(Ev::Eof(conn));
                return;
            };
            let mut blob = vec![0u8; len];
            if reader.read_exact(&mut blob).is_err() {
                let _ = tx.send(Ev::Eof(conn));
                return;
            }
            let _ = tx.send(Ev::Spans(blob));
        } else if !trimmed.is_empty() {
            let _ = tx.send(Ev::Line(conn, trimmed));
        }
    }
}

/// Drive epoch formation: start a new epoch when every live active
/// member is pending, finish an in-flight one when its addresses (and
/// checkpoint, if needed) have arrived.
fn maybe_advance(
    cfg: &ElasticConfig,
    members: &mut BTreeMap<u64, Member>,
    dead: &BTreeSet<u64>,
    prep: &mut Option<PrepState>,
    epochs: &mut usize,
    membership: &mut Vec<(usize, Vec<u64>)>,
) -> Result<()> {
    let p = &cfg.params;
    if let Some(ps) = prep.as_mut() {
        let ready = ps.ranks.iter().all(|u| members.get(u).map_or(false, |m| m.addr.is_some()))
            && (!ps.need_blob || ps.blob.is_some());
        if !ready {
            return Ok(());
        }
        let ps = prep.take().expect("checked above");
        let addrs: Vec<SocketAddr> = ps
            .ranks
            .iter()
            .map(|u| members[u].addr.expect("checked above"))
            .collect();
        let blob = ps.blob.unwrap_or_default();
        let world = ps.ranks.len();
        let obs = u8::from(p.obs || cfg.trace_out.is_some());
        let mut line = format!(
            "epoch {} {} {} {} {} {} {} {obs} {}",
            ps.resume, ps.until, p.steps, p.shards, p.elems, p.seed, p.compute_us, world
        );
        for a in &addrs {
            line.push(' ');
            line.push_str(&a.to_string());
        }
        line.push(' ');
        line.push_str(&blob.len().to_string());
        line.push('\n');
        for u in &ps.ranks {
            let m = members.get_mut(u).expect("ranked member");
            m.writer.write_all(line.as_bytes()).context("send epoch line")?;
            if !blob.is_empty() {
                m.writer.write_all(&blob).context("send checkpoint blob")?;
            }
            m.pending = false;
            m.addr = None;
        }
        *epochs += 1;
        membership.push((ps.resume, ps.ranks.clone()));
        return Ok(());
    }
    // Gather phase: is everyone who should train next ready? The first
    // pass estimates the resume step over every pending member to settle
    // the membership; the real resume is then the max completed step of
    // the actual participants (a departing member can be ahead of
    // survivors after a mid-epoch death — it cannot anchor their epoch).
    let est = members
        .iter()
        .filter(|(u, m)| m.pending && !dead.contains(u))
        .map(|(_, m)| m.completed)
        .max();
    let Some(est) = est else { return Ok(()) };
    if est >= p.steps {
        return Ok(());
    }
    let active: Vec<u64> =
        cfg.plan.active_at(est).into_iter().filter(|u| !dead.contains(u)).collect();
    anyhow::ensure!(!active.is_empty(), "every member of the cohort died at step {est}");
    // Scheduled leavers that are past their exit step get the goodbye.
    for (u, at) in &cfg.plan.leaves {
        if *at <= est && !dead.contains(u) {
            if let Some(m) = members.get_mut(u) {
                if !m.byed && m.done.is_none() {
                    let _ = writeln!(m.writer, "ebye");
                    m.byed = true;
                    m.pending = false;
                }
            }
        }
    }
    let participants: Vec<u64> = active
        .iter()
        .copied()
        .filter(|u| members.get(u).map_or(true, |m| m.done.is_none()))
        .collect();
    let all_pending =
        participants.iter().all(|u| members.get(u).map_or(false, |m| m.pending));
    if participants.is_empty() || !all_pending {
        return Ok(());
    }
    let resume = participants
        .iter()
        .map(|u| members[u].completed)
        .max()
        .expect("participants is non-empty");
    if resume >= p.steps {
        return Ok(());
    }
    let until = cfg.plan.next_boundary(resume, p.steps);
    anyhow::ensure!(until > resume, "degenerate epoch {resume}..{until}");
    let need_blob =
        resume > 0 && participants.iter().any(|u| members[u].completed < resume);
    let blob_from = need_blob.then(|| {
        *participants
            .iter()
            .find(|u| members[u].completed == resume)
            .expect("resume is the max completed of the participants")
    });
    if let Some(src) = blob_from {
        let m = members.get_mut(&src).expect("participant");
        writeln!(m.writer, "eparams?").context("request checkpoint")?;
    }
    for (rank, u) in participants.iter().enumerate() {
        let extra = cfg
            .fault
            .straggle
            .iter()
            .find(|(su, _)| su == u)
            .map_or(0, |(_, e)| *e);
        let m = members.get_mut(u).expect("participant");
        m.addr = None;
        writeln!(m.writer, "eprep {} {rank} {} {extra}", *epochs, participants.len())
            .context("send eprep")?;
    }
    *prep = Some(PrepState {
        resume,
        until,
        ranks: participants,
        need_blob,
        blob: None,
        blob_from,
    });
    Ok(())
}

/// When every live member of the final cohort has reported `edone`,
/// verify the checksums agree and assemble the report.
fn maybe_finish(
    cfg: &ElasticConfig,
    members: &mut BTreeMap<u64, Member>,
    dead: &BTreeSet<u64>,
    epochs: usize,
    recoveries: usize,
    membership: &[(usize, Vec<u64>)],
    spans: &[crate::obs::SpanRecord],
) -> Result<Option<ElasticReport>> {
    let p = &cfg.params;
    let finalists: Vec<u64> =
        cfg.plan.active_at(p.steps).into_iter().filter(|u| !dead.contains(u)).collect();
    if finalists.is_empty()
        || !finalists.iter().all(|u| members.get(u).map_or(false, |m| m.done.is_some()))
    {
        return Ok(None);
    }
    let checksums: Vec<(u64, u64)> =
        finalists.iter().map(|u| (*u, members[u].done.expect("checked"))).collect();
    let first = checksums[0].1;
    anyhow::ensure!(
        checksums.iter().all(|(_, c)| *c == first),
        "final checksums diverged across the cohort: {checksums:x?}"
    );
    // Release everyone still connected (finished workers, parked joiners
    // that never activated).
    for (_, m) in members.iter_mut() {
        if !m.byed {
            let _ = writeln!(m.writer, "ebye");
            m.byed = true;
        }
    }
    let rings: Vec<(u64, &FeedbackRing)> =
        members.iter().map(|(u, m)| (*u, &m.ring)).collect();
    let stragglers = straggler_scores(&rings, p.straggler_window, p.straggler_threshold);
    // Replay the same rings through the online detector so a straggler
    // shows up as a Detection — the format the serve daemon stamps into
    // job telemetry — not just a score row.
    let detections = crate::obs::detect::straggler_onset(
        &rings,
        p.straggler_window,
        p.straggler_threshold,
        p.steps as u64,
    );
    if let Some(path) = &cfg.trace_out {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, crate::obs::span::chrome_trace_json(spans))
            .with_context(|| format!("write chrome trace to {}", path.display()))?;
    }
    Ok(Some(ElasticReport {
        checksum: first,
        steps: p.steps,
        epochs,
        recoveries,
        final_world: finalists.len(),
        membership: membership.to_vec(),
        stragglers,
        detections,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params(steps: usize, shards: usize) -> ElasticParams {
        ElasticParams {
            shards,
            elems: 256,
            steps,
            seed: 0x5eed,
            compute_us: 0,
            rendezvous_timeout: Duration::from_secs(30),
            straggler_window: 8,
            straggler_threshold: 3.0,
            obs: false,
        }
    }

    #[test]
    fn shard_range_tiles_every_shard_exactly_once() {
        for world in 1..=8 {
            for shards in world..=world * 5 {
                let mut owned = vec![0usize; shards];
                for rank in 0..world {
                    for s in shard_range(rank, world, shards) {
                        owned[s] += 1;
                    }
                }
                assert!(owned.iter().all(|c| *c == 1), "w={world} L={shards}: {owned:?}");
            }
        }
    }

    #[test]
    fn oracle_is_deterministic_and_seed_sensitive() {
        let p = quick_params(5, 6);
        assert_eq!(expected_checksum(&p), expected_checksum(&p));
        let mut q = p.clone();
        q.seed ^= 1;
        assert_ne!(expected_checksum(&p), expected_checksum(&q));
    }

    #[test]
    fn fixed_membership_matches_the_oracle() {
        let p = quick_params(4, 4);
        let plan = MembershipPlan { initial: vec![7, 8], ..Default::default() };
        let r = elastic_launch(&ElasticConfig::loopback(p.clone(), plan)).unwrap();
        assert_eq!(r.checksum, expected_checksum(&p));
        assert_eq!(r.epochs, 1);
        assert_eq!(r.recoveries, 0);
        assert_eq!(r.final_world, 2);
    }

    #[test]
    fn scale_out_join_is_bit_identical() {
        let p = quick_params(4, 4);
        let plan = MembershipPlan {
            initial: vec![10, 20],
            joins: vec![(30, 2)],
            ..Default::default()
        };
        let r = elastic_launch(&ElasticConfig::loopback(p.clone(), plan)).unwrap();
        assert_eq!(r.checksum, expected_checksum(&p), "{:?}", r.membership);
        assert_eq!(r.epochs, 2);
        assert_eq!(r.final_world, 3);
        assert_eq!(r.membership[1].1, vec![10, 20, 30]);
    }

    #[test]
    fn scale_in_leave_is_bit_identical() {
        let p = quick_params(4, 4);
        let plan = MembershipPlan {
            initial: vec![1, 2, 3],
            leaves: vec![(3, 2)],
            ..Default::default()
        };
        let r = elastic_launch(&ElasticConfig::loopback(p.clone(), plan)).unwrap();
        assert_eq!(r.checksum, expected_checksum(&p), "{:?}", r.membership);
        assert_eq!(r.epochs, 2);
        assert_eq!(r.final_world, 2);
        assert_eq!(r.membership[1].1, vec![1, 2]);
    }

    #[test]
    fn crash_recovery_replays_the_dead_workers_shards() {
        let p = quick_params(5, 6);
        let plan = MembershipPlan { initial: vec![1, 2, 3], ..Default::default() };
        let mut cfg = ElasticConfig::loopback(p.clone(), plan);
        cfg.fault.die = Some((2, 2));
        let r = elastic_launch(&cfg).unwrap();
        assert_eq!(r.checksum, expected_checksum(&p), "{:?}", r.membership);
        assert!(r.recoveries >= 1);
        assert!(r.epochs >= 2);
        assert_eq!(r.final_world, 2);
        let last = &r.membership.last().unwrap().1;
        assert!(!last.contains(&2), "dead worker re-admitted: {last:?}");
    }

    #[test]
    fn crash_without_recovery_fails_fast_naming_the_worker() {
        let p = ElasticParams {
            rendezvous_timeout: Duration::from_secs(20),
            ..quick_params(4, 4)
        };
        let plan = MembershipPlan { initial: vec![1, 2], ..Default::default() };
        let mut cfg = ElasticConfig::loopback(p, plan);
        cfg.fault.die = Some((2, 1));
        cfg.fault.recovery = false;
        let t0 = Instant::now();
        let err = elastic_launch(&cfg).unwrap_err().to_string();
        let elapsed = t0.elapsed();
        // Either the coordinator saw the drop first (naming worker 2) or
        // the survivor's recv deadline fired first (naming rank 1 = uid 2)
        // — both fail fast and both name the dead party.
        assert!(err.contains("rank 1") || err.contains("worker 2"), "{err}");
        assert!(
            elapsed < Duration::from_secs(15),
            "no-recovery death took {elapsed:?} — that is a wedge, not fail-fast"
        );
    }

    #[test]
    fn straggler_is_flagged_from_the_feedback_rings() {
        let p = ElasticParams { compute_us: 300, ..quick_params(4, 4) };
        let plan = MembershipPlan { initial: vec![5, 6, 7], ..Default::default() };
        let mut cfg = ElasticConfig::loopback(p.clone(), plan);
        cfg.fault.straggle = vec![(6, 8_000)];
        let r = elastic_launch(&cfg).unwrap();
        assert_eq!(r.checksum, expected_checksum(&p));
        let flagged: Vec<u64> =
            r.stragglers.iter().filter(|s| s.straggler).map(|s| s.id).collect();
        assert_eq!(flagged, vec![6], "{:?}", r.stragglers);
        // The same verdict rides the report as a wire-format Detection.
        assert!(
            r.detections.iter().any(|d| d.series == "member.6.compute_s"),
            "{:?}",
            r.detections
        );
    }

    #[test]
    fn obs_run_ships_spans_and_writes_the_coordinator_trace() {
        // Serialize with other tracer-enabling tests: the span ring is
        // process-global and the epoch line flips the tracer on.
        let _serial = crate::obs::span::test_lock();
        let trace = std::env::temp_dir().join("netbn_elastic_obs_test_trace.json");
        let _ = std::fs::remove_file(&trace);
        let p = quick_params(4, 4);
        let plan = MembershipPlan {
            initial: vec![1, 2],
            joins: vec![(3, 2)],
            ..Default::default()
        };
        let mut cfg = ElasticConfig::loopback(p.clone(), plan);
        cfg.trace_out = Some(trace.clone());
        let r = elastic_launch(&cfg).unwrap();
        crate::obs::span::disable();
        assert_eq!(r.checksum, expected_checksum(&p), "{:?}", r.membership);
        assert_eq!(r.epochs, 2, "join at step 2 splits the run");
        let json = std::fs::read_to_string(&trace).unwrap();
        assert!(json.contains("\"traceEvents\""), "{json}");
        for name in ["step.total", "step.grad", "wire.send", "reduce.add", "step.update"] {
            assert!(json.contains(name), "trace is missing {name}: {json}");
        }
        let _ = std::fs::remove_file(&trace);
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let p = quick_params(4, 2);
        let dup = MembershipPlan { initial: vec![1, 1], ..Default::default() };
        assert!(ElasticConfig::loopback(p.clone(), dup).validate().is_err());
        let late = MembershipPlan {
            initial: vec![1],
            joins: vec![(2, 9)],
            ..Default::default()
        };
        assert!(ElasticConfig::loopback(p.clone(), late).validate().is_err());
        // 2 shards cannot cover a 3-wide cohort.
        let wide = MembershipPlan { initial: vec![1, 2, 3], ..Default::default() };
        assert!(ElasticConfig::loopback(p.clone(), wide).validate().is_err());
        // SIGKILL injection needs real processes.
        let mut threaded =
            ElasticConfig::loopback(p, MembershipPlan { initial: vec![1, 2], ..Default::default() });
        threaded.fault.kill = Some((1, 1));
        assert!(threaded.validate().is_err());
        let ok = ElasticConfig::loopback(
            quick_params(4, 4),
            MembershipPlan { initial: vec![1, 2], ..Default::default() },
        );
        ok.validate().unwrap();
    }
}
