//! `netbn launch` — the end-to-end **multi-process** TCP trainer driver.
//!
//! Everything before this module emulates a cluster inside one process.
//! Here the full trainer path runs over *real* process and socket
//! boundaries: a coordinator binds a loopback rendezvous port, spawns `N`
//! worker processes (`netbn _worker`, or threads for in-test smoke runs —
//! same code path either way), and each worker:
//!
//! 1. binds one [`crate::net::mesh::MeshNode`] per transport lane
//!    (`striped:K` ⇒ `K` listeners, i.e. `K` real connections per peer
//!    pair),
//! 2. registers its lane addresses with the coordinator and receives the
//!    full rank-ordered peer table back (the rendezvous),
//! 3. runs `steps` synchronous data-parallel steps — barrier, local
//!    gradient, then the overlap scheduler ([`crate::sched`]): per-layer
//!    modeled backward compute with bucketized all-reduce over the
//!    configured collective (`ring`/`tree`/`ps`/`hier:<g>`), overlapped
//!    (`--overlap buckets`) or serialized (`--overlap off`), then the
//!    parameter update — timing collective-busy seconds separately from
//!    the step,
//! 4. reports per-step timings and an FNV-1a checksum of its final
//!    parameter bits.
//!
//! The coordinator aggregates: per-step wall clock (slowest worker),
//! effective **bus bandwidth** (NCCL's convention — the ring-equivalent
//! wire volume `2·S·(N−1)/N` over the measured all-reduce time,
//! whichever algorithm ran), and the **bit-identity** of the final
//! tensors across workers, which is the e2e correctness gate: one flipped
//! bit anywhere in transport, striping or collective shows up as a
//! checksum mismatch.
//!
//! **Fault model**: every mid-step collective recv carries a deadline
//! derived from recent step times
//! ([`crate::net::mesh::MeshEndpoint::set_recv_timeout`]), so a worker
//! that dies after rendezvous surfaces as a deadline error naming the
//! absent rank. The survivor poisons its remaining lanes, reports an
//! `abort` line, and the coordinator — which also watches every worker
//! stream for EOF while collecting — fails the launch fast instead of
//! wedging. The rendezvous phase is bounded by `--rendezvous-timeout`
//! (60 s default). For *elastic* membership, checkpoint/rollback
//! recovery and scripted fault injection on top of this driver, see
//! [`super::elastic`].
//!
//! Multi-host: the coordinator binds `--bind` (default loopback) and
//! `--spawn external` skips spawning entirely — workers are started by
//! hand on other machines with `netbn _worker --coordinator host:port`,
//! and lane listeners bind the interface that routes to the coordinator
//! rather than hardcoding loopback.

use crate::collectives::{barrier, ring};
use crate::config::{CollectiveKind, Compression, OverlapMode, TransportKind};
use crate::net::mesh::MeshNode;
use crate::net::striped::{StripeConfig, StripedEndpoint, StripedTransport};
use crate::net::tcp::connect_retry;
use crate::net::transport::{SingleStream, Transport};
use crate::net::{tag, tags, Endpoint};
use crate::sched::bucket::{mb_to_threshold, plan_buckets, ready_order_from_ranges};
use crate::sched::{layer_ranges, run_step, AsyncCollectiveEngine};
use crate::topology::WorkerId;
use crate::tune::{AutoTuner, KnobPoint, KnobSpace, StepFeedback, TunerConfig};
use crate::util::Rng;
use crate::Result;
use anyhow::Context;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How workers are brought up: real OS processes (the `netbn launch`
/// default — the point of the driver) or threads running the identical
/// worker code (the in-test smoke path; rendezvous and data still cross
/// real loopback sockets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpawnMode {
    Process,
    Thread,
    /// Spawn nothing: serve the rendezvous and wait for workers started
    /// by hand (`netbn _worker --coordinator host:port`), possibly on
    /// other machines — the multi-host path.
    External,
}

impl SpawnMode {
    pub fn parse(s: &str) -> Option<SpawnMode> {
        match s.to_ascii_lowercase().as_str() {
            "process" => Some(SpawnMode::Process),
            "thread" => Some(SpawnMode::Thread),
            "external" => Some(SpawnMode::External),
            _ => None,
        }
    }
}

/// Per-worker parameters, identical on every rank (and serialized onto
/// the `netbn _worker` command line in process mode).
#[derive(Clone, Debug)]
pub struct WorkerParams {
    pub world: usize,
    pub steps: usize,
    /// Gradient tensor length (f32 elements).
    pub elems: usize,
    pub transport: TransportKind,
    pub collective: CollectiveKind,
    /// Compute/communication overlap policy: `Off` submits every bucket
    /// after the modeled backward finishes (the serialized baseline);
    /// `Buckets` flushes each bucket into the async engine as its last
    /// layer completes. Bit-identical either way (same buckets, same
    /// collective order).
    pub overlap: OverlapMode,
    /// Bucketizer threshold in MB (`<= 0` = one bucket for the whole
    /// gradient).
    pub bucket_mb: f64,
    /// Synthetic backward layers the gradient is split across (the
    /// overlap scheduler's emission granularity).
    pub layers: usize,
    /// Total modeled backward compute per step, microseconds, spread
    /// evenly across the layers (0 = no modeled compute — pure wire
    /// benchmark, nothing to overlap under).
    pub compute_us: u64,
    /// Online autotuning: rank 0 runs the [`AutoTuner`] over the stripe
    /// chunk size and broadcasts knob changes to every rank at step
    /// boundaries over the mesh control channel ([`tags::CONTROL`]).
    /// Chunking is arithmetic-neutral (it changes how bytes move, never
    /// what they sum to), so autotuned runs stay FNV-bit-identical to
    /// static runs — requires a striped transport.
    pub autotune: bool,
    /// The tuner's chunk-size axis, KB (only read when `autotune`).
    pub chunk_kbs: Vec<usize>,
    /// Modeled per-stream software ceiling, Gbps (0 = unshaped). Only
    /// meaningful with a striped transport.
    pub gate_gbps: f64,
    /// Scripted mid-run NIC event: at this step every rank drops its
    /// per-stream gate to `drop_gbps` (0 = never) — the environment
    /// change `autotune_adapt` recovers from.
    pub drop_at_step: usize,
    pub drop_gbps: f64,
    pub seed: u64,
    /// Observability: enable span tracing, ship per-step span snapshots
    /// to the coordinator over the mesh control channel, and report the
    /// per-step time breakdown + link-utilization timeline
    /// ([`crate::obs`]). Off by default — the disabled instrumentation
    /// costs one atomic load per span site.
    pub obs: bool,
    /// Rank 0 writes the merged, clock-aligned span stream as Chrome
    /// trace-event JSON here (implies `obs`); load it in Perfetto.
    pub trace_out: Option<std::path::PathBuf>,
}

/// One `netbn launch` invocation.
#[derive(Clone, Debug)]
pub struct LaunchConfig {
    pub params: WorkerParams,
    pub spawn: SpawnMode,
    /// When set, the coordinator writes one `step_feedback` JSONL record
    /// per step (slowest-worker timings) — the trace `netbn tune
    /// --from-trace` replays.
    pub feedback_out: Option<std::path::PathBuf>,
    /// Bound on the whole rendezvous phase (`--rendezvous-timeout`,
    /// 60 s default): a worker that never registers fails the launch
    /// after this long instead of hanging it.
    pub rendezvous_timeout: Duration,
    /// Coordinator bind address (`127.0.0.1:0` default; a routable
    /// interface + fixed port for `--spawn external` multi-host runs).
    pub bind: SocketAddr,
}

/// The default coordinator bind: loopback, OS-assigned port.
pub fn loopback_bind() -> SocketAddr {
    "127.0.0.1:0".parse().expect("loopback literal")
}

impl LaunchConfig {
    pub fn validate(&self) -> Result<()> {
        let p = &self.params;
        anyhow::ensure!(
            self.rendezvous_timeout > Duration::ZERO,
            "rendezvous timeout must be > 0"
        );
        anyhow::ensure!(p.world >= 1, "launch needs >= 1 worker");
        anyhow::ensure!(p.steps >= 1, "launch needs >= 1 step");
        anyhow::ensure!(p.elems >= 1, "launch needs >= 1 gradient element");
        anyhow::ensure!(p.layers >= 1, "launch needs >= 1 backward layer");
        anyhow::ensure!(
            p.layers <= p.elems,
            "more layers ({}) than gradient elements ({})",
            p.layers,
            p.elems
        );
        anyhow::ensure!(p.bucket_mb.is_finite(), "bucket-mb must be finite");
        if let CollectiveKind::Hierarchical { group_size } = p.collective {
            anyhow::ensure!(group_size >= 1, "hier group size must be >= 1");
        }
        if let TransportKind::Striped { streams } = p.transport {
            anyhow::ensure!((1..=64).contains(&streams), "launch striped streams in 1..=64");
        }
        anyhow::ensure!(
            p.gate_gbps >= 0.0 && p.gate_gbps.is_finite(),
            "gate-gbps must be >= 0 and finite"
        );
        if p.gate_gbps > 0.0 || p.autotune {
            anyhow::ensure!(
                matches!(p.transport, TransportKind::Striped { .. }),
                "--autotune and --gate-gbps act on the striped transport's \
                 per-stream pipelines; use --transport striped:N"
            );
        }
        if p.autotune {
            anyhow::ensure!(!p.chunk_kbs.is_empty(), "autotune needs >= 1 chunk-kb candidate");
            for &kb in &p.chunk_kbs {
                // Same bound as every other chunk_kb surface (one knob,
                // one range — see crate::tune::knobs).
                anyhow::ensure!(
                    crate::tune::knobs::CHUNK_KB_RANGE.contains(&kb),
                    "chunk-kb candidate {kb} must be in {}..={}",
                    crate::tune::knobs::CHUNK_KB_RANGE.start(),
                    crate::tune::knobs::CHUNK_KB_RANGE.end()
                );
            }
        }
        if p.drop_at_step > 0 {
            anyhow::ensure!(
                p.gate_gbps > 0.0 && p.drop_gbps > 0.0 && p.drop_gbps.is_finite(),
                "a scripted rate drop needs --gate-gbps and --drop-gbps > 0"
            );
            anyhow::ensure!(
                p.drop_at_step < p.steps,
                "drop-at-step ({}) must fall inside the run ({} steps)",
                p.drop_at_step,
                p.steps
            );
        }
        Ok(())
    }
}

/// What the coordinator learned from a finished run.
#[derive(Clone, Debug)]
pub struct LaunchReport {
    pub workers: usize,
    pub steps: usize,
    /// Per step: wall clock of the slowest worker (post-barrier).
    pub step_wall_s: Vec<f64>,
    /// Per step: collective-busy time of the slowest worker — the seconds
    /// its engine thread spent inside all-reduces, including spans
    /// overlapped under compute (so the figure is comparable across
    /// `--overlap` modes).
    pub allreduce_s: Vec<f64>,
    /// NCCL-convention bus bandwidth over the measured all-reduce times.
    pub effective_bus_gbps: f64,
    /// FNV-1a checksum of each rank's final parameter bits.
    pub checksums: Vec<u64>,
    /// All ranks ended bit-identical.
    pub identical: bool,
    /// Rank 0's applied chunk-size trajectory when `--autotune` was on:
    /// `(first step the value was active, chunk KB)`; empty otherwise.
    pub knob_trajectory: Vec<(u64, usize)>,
    /// Per-step time breakdown from the merged span stream (`--obs`
    /// runs; empty otherwise): barrier / compute / serialize / wire /
    /// reduce against the measured step wall, averaged across ranks.
    pub breakdown: Vec<crate::obs::StepBreakdown>,
    /// Mean delivered wire rate per rank, bytes/sec, measured from
    /// `wire.send` spans over the union of their wall intervals (0 when
    /// obs was off or nothing hit the wire).
    pub wire_mean_bps: f64,
    /// Time-bucketed link-utilization timeline `(t_seconds, bytes/sec
    /// per rank)` over the whole run (empty when obs was off).
    pub util_timeline: Vec<(f64, f64)>,
    /// Online anomaly detections over rank 0's per-step bus-bandwidth
    /// series ([`crate::obs::detect`], throughput config) — a scripted
    /// or real mid-run rate drop shows up here within a few steps.
    pub detections: Vec<crate::obs::Detection>,
}

impl LaunchReport {
    /// The e2e pass criterion: bit-identical tensors and a non-zero
    /// effective bandwidth (for a multi-worker run — a single worker
    /// moves no wire bytes by construction).
    pub fn passed(&self) -> bool {
        self.identical && (self.workers == 1 || self.effective_bus_gbps > 0.0)
    }

    /// The per-step timing table both `netbn launch` and the
    /// `e2e_tcp_smoke` scenario render — one formatter, two surfaces.
    pub fn step_table(&self) -> crate::report::Table {
        let mut t = crate::report::Table::new(
            format!(
                "e2e launch: {} workers, {} steps over loopback TCP",
                self.workers, self.steps
            ),
            &["step", "wall (slowest)", "all-reduce (slowest)"],
        );
        for (i, (w, a)) in self.step_wall_s.iter().zip(&self.allreduce_s).enumerate() {
            t.row(vec![
                i.to_string(),
                crate::util::fmt::secs(*w),
                crate::util::fmt::secs(*a),
            ]);
        }
        t
    }
}

/// Striped lanes use a smaller chunk than the in-process default so
/// smoke-test-sized tensors (hundreds of KB) genuinely pipeline instead
/// of traveling fused.
fn launch_stripe_config(streams: usize) -> StripeConfig {
    StripeConfig { streams, chunk_bytes: 32 << 10, credit_window: 4 }
}

/// The striped transport a launch run binds (gate included). ONE
/// construction site: both the lane count and the bound endpoint derive
/// from here, so they cannot desynchronize.
fn launch_striped_transport(p: &WorkerParams, streams: usize) -> StripedTransport {
    let cfg = launch_stripe_config(streams);
    if p.gate_gbps > 0.0 {
        StripedTransport::with_stream_ceiling(cfg, crate::gbps_to_bytes_per_sec(p.gate_gbps))
    } else {
        StripedTransport::new(cfg)
    }
}

/// Mesh listeners (= real connections) per peer pair — the coordinator's
/// and the workers' shared lane count.
fn launch_lanes(p: &WorkerParams) -> usize {
    match p.transport {
        TransportKind::Striped { streams } => {
            launch_striped_transport(p, streams).lanes()
        }
        _ => SingleStream.lanes(),
    }
}

/// The knob grid the launch tuner searches: only the chunk axis is open —
/// every other knob is frozen at the run's static value. Chunking is the
/// one knob the striped endpoint can retune at a step boundary without
/// touching the arithmetic (stripes are physical listeners fixed at
/// rendezvous; bucket plan and collective pick the summation order, which
/// must match the static run bit for bit).
fn launch_knob_space(p: &WorkerParams, streams: usize) -> KnobSpace {
    KnobSpace {
        bucket_mbs: vec![p.bucket_mb.max(0.0)],
        stripes: vec![streams],
        chunk_kbs: p.chunk_kbs.clone(),
        collectives: vec![p.collective],
        compressions: vec![Compression::None],
    }
}

/// The static starting point (the endpoint's bound chunk size).
fn launch_initial_point(p: &WorkerParams, streams: usize) -> KnobPoint {
    KnobPoint {
        bucket_mb: p.bucket_mb.max(0.0),
        stripes: streams,
        chunk_kb: launch_stripe_config(streams).chunk_bytes >> 10,
        collective: p.collective,
        compression: Compression::None,
    }
}

/// FNV-1a over a parameter vector's exact bit patterns (little-endian,
/// the wire byte order — so the checksum IS the bytes a peer would see).
pub fn tensor_checksum(xs: &[f32]) -> u64 {
    crate::util::prop::fnv1a(crate::collectives::f32s_as_bytes(xs))
}

/// Run a full launch: bind the rendezvous port, bring up the workers,
/// serve the rendezvous + collection protocol, aggregate the report.
pub fn launch(cfg: &LaunchConfig) -> Result<LaunchReport> {
    cfg.validate()?;
    // SIGINT/SIGTERM flip the shutdown flag; the coordinator loops poll
    // it and bail, and the process-mode error path below kills + reaps
    // every `_worker` child instead of orphaning them.
    crate::util::signal::install();
    let listener = TcpListener::bind(cfg.bind).context("bind coordinator port")?;
    let addr = listener.local_addr()?;
    let p = cfg.params.clone();
    let report = match cfg.spawn {
        SpawnMode::Thread => {
            let mut workers = Vec::new();
            for rank in 0..p.world {
                let p = p.clone();
                workers.push(std::thread::spawn(move || worker_entry(rank, addr, &p)));
            }
            let report = coordinator_serve(&listener, &p, None, cfg.rendezvous_timeout);
            for (rank, h) in workers.into_iter().enumerate() {
                let joined =
                    h.join().map_err(|_| anyhow::anyhow!("worker {rank} panicked"));
                // A failed launch already carries the root cause; the
                // workers' own abort errors would only mask it.
                if report.is_ok() {
                    joined?.with_context(|| format!("worker {rank} failed"))?;
                }
            }
            report
        }
        SpawnMode::External => {
            eprintln!(
                "coordinator listening on {addr}: start {} workers with \
                 `netbn _worker --coordinator {addr} --rank <r> ...`",
                p.world
            );
            coordinator_serve(&listener, &p, None, cfg.rendezvous_timeout)
        }
        SpawnMode::Process => {
            // NETBN_WORKER_EXE lets integration tests point the spawn at
            // the cargo-built binary when the test harness is the parent.
            let exe = std::env::var_os("NETBN_WORKER_EXE")
                .map(std::path::PathBuf::from)
                .map_or_else(std::env::current_exe, Ok)
                .context("locate the netbn binary")?;
            let mut children = Vec::new();
            for rank in 0..p.world {
                let child = std::process::Command::new(&exe)
                    .arg("_worker")
                    .arg("--rank")
                    .arg(rank.to_string())
                    .arg("--world")
                    .arg(p.world.to_string())
                    .arg("--coordinator")
                    .arg(addr.to_string())
                    .arg("--steps")
                    .arg(p.steps.to_string())
                    .arg("--elems")
                    .arg(p.elems.to_string())
                    .arg("--transport")
                    .arg(p.transport.to_string())
                    .arg("--collective")
                    .arg(p.collective.to_string())
                    .arg("--overlap")
                    .arg(p.overlap.to_string())
                    .arg("--bucket-mb")
                    .arg(p.bucket_mb.to_string())
                    .arg("--layers")
                    .arg(p.layers.to_string())
                    .arg("--compute-us")
                    .arg(p.compute_us.to_string())
                    .arg("--autotune")
                    .arg(if p.autotune { "true" } else { "false" })
                    .arg("--chunk-kbs")
                    .arg(
                        p.chunk_kbs
                            .iter()
                            .map(|k| k.to_string())
                            .collect::<Vec<_>>()
                            .join(","),
                    )
                    .arg("--gate-gbps")
                    .arg(p.gate_gbps.to_string())
                    .arg("--drop-at-step")
                    .arg(p.drop_at_step.to_string())
                    .arg("--drop-gbps")
                    .arg(p.drop_gbps.to_string())
                    .arg("--seed")
                    .arg(p.seed.to_string())
                    .arg("--obs")
                    .arg(if p.obs { "true" } else { "false" })
                    .args(
                        p.trace_out
                            .iter()
                            .flat_map(|t| [std::ffi::OsString::from("--trace-out"), t.into()]),
                    )
                    .spawn()
                    .with_context(|| format!("spawn worker process {rank}"))?;
                children.push(child);
            }
            let report =
                coordinator_serve(&listener, &p, Some(&mut children), cfg.rendezvous_timeout);
            if let Err(e) = report {
                // The coordinator's error is the root cause; kill and reap
                // the children without letting their (killed) exit
                // statuses mask it.
                for c in &mut children {
                    let _ = c.kill();
                }
                for mut c in children {
                    let _ = c.wait();
                }
                return Err(e);
            }
            for (rank, mut c) in children.into_iter().enumerate() {
                let status = c.wait().with_context(|| format!("wait for worker {rank}"))?;
                anyhow::ensure!(status.success(), "worker process {rank} exited with {status}");
            }
            report
        }
    }?;
    if let Some(path) = &cfg.feedback_out {
        write_feedback(path, &p, &report)
            .with_context(|| format!("write step feedback to {}", path.display()))?;
    }
    Ok(report)
}

/// One step's feedback derivation — the SINGLE definition of the
/// wire-bytes/busbw formula, shared by rank 0's online tuning loop and
/// the coordinator's `--feedback-out` writer. Note the *inputs* differ
/// by design: the online tuner observes rank 0's own per-step timings,
/// while the recorded trace carries the coordinator's slowest-worker
/// aggregates — same formula, cluster-level view.
fn step_feedback(
    p: &WorkerParams,
    step: u64,
    wall_s: f64,
    compute_s: f64,
    comm_busy_s: f64,
) -> StepFeedback {
    let wire = ring::wire_bytes_per_worker((p.elems * 4) as f64, p.world);
    StepFeedback {
        step,
        wall_s,
        compute_s,
        comm_busy_s,
        busbw_gbps: if comm_busy_s > 0.0 {
            crate::bytes_per_sec_to_gbps(wire / comm_busy_s)
        } else {
            0.0
        },
    }
}

/// One `step_feedback` record per step (slowest-worker figures), the
/// producer side of `netbn tune --from-trace`.
fn write_feedback(
    path: &std::path::Path,
    p: &WorkerParams,
    r: &LaunchReport,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    for s in 0..r.steps {
        let wall = r.step_wall_s[s];
        let busy = r.allreduce_s[s];
        let fb = step_feedback(p, s as u64, wall, (wall - busy).max(0.0), busy);
        writeln!(out, "{}", fb.to_record(0).to_json_line())?;
    }
    out.flush()?;
    Ok(())
}

/// Accept `world` workers, run the rendezvous, collect the results. In
/// process mode `children` lets the rendezvous loop detect a worker that
/// died before registering and fail fast with its exit status instead of
/// waiting out the deadline.
fn coordinator_serve(
    listener: &TcpListener,
    p: &WorkerParams,
    mut children: Option<&mut Vec<std::process::Child>>,
    rendezvous_timeout: Duration,
) -> Result<LaunchReport> {
    let lanes = launch_lanes(p);
    let mut streams: Vec<Option<TcpStream>> = (0..p.world).map(|_| None).collect();
    let mut readers: Vec<Option<BufReader<TcpStream>>> = (0..p.world).map(|_| None).collect();
    // lane_addrs[rank][lane]
    let mut lane_addrs: Vec<Vec<SocketAddr>> = vec![Vec::new(); p.world];
    // Non-blocking accept with a deadline: a worker that dies before
    // registering must fail the launch, not hang it (a blocking accept
    // would wait forever for the hello that never comes).
    listener.set_nonblocking(true).context("set rendezvous listener non-blocking")?;
    let rendezvous_deadline = Instant::now() + rendezvous_timeout;
    for _ in 0..p.world {
        let stream = loop {
            match listener.accept() {
                Ok((stream, _)) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    anyhow::ensure!(
                        !crate::util::signal::triggered(),
                        "interrupted (SIGINT/SIGTERM) during worker rendezvous"
                    );
                    if let Some(children) = children.as_deref_mut() {
                        for (rank, c) in children.iter_mut().enumerate() {
                            if let Ok(Some(status)) = c.try_wait() {
                                anyhow::ensure!(
                                    status.success(),
                                    "worker process {rank} exited with {status} before registering"
                                );
                            }
                        }
                    }
                    let missing = streams.iter().filter(|s| s.is_none()).count();
                    anyhow::ensure!(
                        Instant::now() < rendezvous_deadline,
                        "rendezvous timed out: {missing} of {} workers never registered",
                        p.world
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e).context("accept worker rendezvous"),
            }
        };
        // Accepted sockets may inherit non-blocking on some platforms;
        // the protocol below wants plain blocking reads.
        stream.set_nonblocking(false).context("restore blocking rendezvous stream")?;
        stream.set_read_timeout(Some(Duration::from_secs(120))).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line).context("read worker hello")?;
        let mut it = line.split_whitespace();
        anyhow::ensure!(it.next() == Some("hello"), "bad rendezvous greeting {line:?}");
        let rank: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("hello without a rank: {line:?}"))?;
        anyhow::ensure!(rank < p.world, "hello from rank {rank} in a world of {}", p.world);
        anyhow::ensure!(streams[rank].is_none(), "rank {rank} registered twice");
        let addrs: Vec<SocketAddr> = it
            .map(|s| s.parse().context("bad lane address in hello"))
            .collect::<Result<_>>()?;
        anyhow::ensure!(
            addrs.len() == lanes,
            "rank {rank} registered {} lane addresses, transport needs {lanes}",
            addrs.len()
        );
        lane_addrs[rank] = addrs;
        streams[rank] = Some(stream);
        readers[rank] = Some(reader);
    }
    // Broadcast the full rank-major peer table.
    let mut peers = format!("peers {lanes} {}", p.world);
    for rank_addrs in &lane_addrs {
        for a in rank_addrs {
            peers.push(' ');
            peers.push_str(&a.to_string());
        }
    }
    peers.push('\n');
    for s in streams.iter_mut().flatten() {
        s.write_all(peers.as_bytes()).context("send peer table")?;
    }
    // Collect results. The training loop runs for as long as steps ×
    // tensor size dictate, so there is no overall clock here — instead
    // every stream is polled with a short read timeout so a worker that
    // DIES mid-run (EOF) or ABORTS (deadline error in a collective)
    // fails the launch immediately, naming the rank, while healthy slow
    // runs wait as long as they need. This is the fix for the old
    // "wedge on mid-step death" limitation.
    for s in streams.iter().flatten() {
        s.set_read_timeout(Some(Duration::from_millis(300))).ok();
    }
    let obs_on = p.obs || p.trace_out.is_some();
    let mut step_wall = vec![0.0f64; p.steps];
    let mut ar = vec![0.0f64; p.steps];
    let mut checksums = vec![0u64; p.world];
    let mut knob_trajectory: Vec<(u64, usize)> = Vec::new();
    let mut breakdown: Vec<crate::obs::StepBreakdown> = Vec::new();
    let mut wire_mean_bps = 0.0f64;
    let mut util_timeline: Vec<(f64, f64)> = Vec::new();
    let mut detections: Vec<crate::obs::Detection> = Vec::new();
    let mut collected = vec![false; p.world];
    // Partial-line accumulators: a timed-out read_line keeps the bytes
    // it already consumed in the String, so each rank's buffer persists
    // across polls.
    let mut lines: Vec<String> = vec![String::new(); p.world];
    while collected.iter().any(|c| !*c) {
        anyhow::ensure!(
            !crate::util::signal::triggered(),
            "interrupted (SIGINT/SIGTERM) while collecting worker results"
        );
        if let Some(children) = children.as_deref_mut() {
            for (rank, c) in children.iter_mut().enumerate() {
                if !collected[rank] {
                    if let Ok(Some(status)) = c.try_wait() {
                        anyhow::ensure!(
                            status.success(),
                            "worker process {rank} exited with {status} mid-run"
                        );
                    }
                }
            }
        }
        let mut progressed = false;
        for rank in 0..p.world {
            if collected[rank] {
                continue;
            }
            let reader = readers[rank].as_mut().expect("registered above");
            let line = &mut lines[rank];
            match reader.read_line(line) {
                Ok(0) => anyhow::bail!(
                    "worker {rank} died mid-run (connection dropped after step \
                     reports stopped) — peers will see its absence as a recv \
                     deadline; see `netbn launch --help` for the fault model"
                ),
                Ok(_) if line.ends_with('\n') => progressed = true,
                Ok(_) => {} // mid-line; keep accumulating
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(e) => {
                    return Err(e).with_context(|| format!("read done from rank {rank}"))
                }
            }
            if !line.ends_with('\n') {
                continue;
            }
            let line = std::mem::take(&mut lines[rank]);
            let mut it = line.split_whitespace();
            match it.next() {
                Some("done") => {}
                Some("abort") => {
                    let abort_rank = it.next().unwrap_or("?").to_string();
                    let reason: String = it.collect::<Vec<_>>().join(" ");
                    anyhow::bail!("worker {abort_rank} aborted mid-run: {reason}");
                }
                _ => anyhow::bail!("bad completion line {line:?}"),
            }
            let done_rank: usize = it
                .next()
                .and_then(|s| s.parse().ok())
                .with_context(|| format!("done without a rank: {line:?}"))?;
            anyhow::ensure!(done_rank == rank, "rank {rank} stream reported rank {done_rank}");
            let checksum = it
                .next()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .with_context(|| format!("done without a checksum: {line:?}"))?;
            let ar_times = parse_csv_f64(it.next().unwrap_or(""), p.steps)
                .with_context(|| format!("rank {rank} all-reduce timings"))?;
            let walls = parse_csv_f64(it.next().unwrap_or(""), p.steps)
                .with_context(|| format!("rank {rank} step timings"))?;
            // Rank 0 appends its knob trajectory ("-" when not autotuning).
            let traj_field = it.next().unwrap_or("-");
            if rank == 0 && traj_field != "-" {
                knob_trajectory = parse_trajectory(traj_field)
                    .with_context(|| format!("rank 0 knob trajectory {traj_field:?}"))?;
            }
            // Rank 0 appends the obs aggregates ("-" fields when obs off).
            let bd_field = it.next().unwrap_or("-");
            let wire_field = it.next().unwrap_or("-");
            let tl_field = it.next().unwrap_or("-");
            if rank == 0 {
                if bd_field != "-" {
                    breakdown = parse_breakdown(bd_field)
                        .with_context(|| format!("rank 0 breakdown {bd_field:?}"))?;
                }
                if wire_field != "-" {
                    wire_mean_bps = wire_field
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad wire rate {wire_field:?}"))?;
                }
                if tl_field != "-" {
                    util_timeline = parse_timeline(tl_field)
                        .with_context(|| format!("rank 0 util timeline {tl_field:?}"))?;
                }
            }
            // Rank 0 appends its online busbw detections ("-" when the
            // series stayed clean; absent entirely from old workers).
            let det_field = it.next().unwrap_or("-");
            if rank == 0 {
                detections = crate::obs::detect::parse_detections(det_field, "busbw_gbps")
                    .with_context(|| format!("rank 0 detections {det_field:?}"))?;
            }
            // Obs runs: rank 0 follows its done line with `trace <len>`
            // plus the merged span stream, so `--trace-out` lands on the
            // coordinator's filesystem even when rank 0 is a remote
            // external worker (which writes its own local copy too).
            if rank == 0 && obs_on {
                let spans = read_span_trace(readers[0].as_mut().expect("registered above"))?;
                if let Some(path) = &p.trace_out {
                    if let Some(dir) = path.parent() {
                        if !dir.as_os_str().is_empty() {
                            std::fs::create_dir_all(dir)?;
                        }
                    }
                    std::fs::write(path, crate::obs::span::chrome_trace_json(&spans))
                        .with_context(|| format!("write chrome trace to {}", path.display()))?;
                }
            }
            checksums[rank] = checksum;
            for s in 0..p.steps {
                ar[s] = ar[s].max(ar_times[s]);
                step_wall[s] = step_wall[s].max(walls[s]);
            }
            collected[rank] = true;
        }
        if !progressed {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    // Release the workers (they hold their fabrics open until everyone is
    // done, so no rank tears down lanes a peer still needs).
    for s in streams.iter_mut().flatten() {
        let _ = s.write_all(b"bye\n");
    }
    let identical = checksums.windows(2).all(|w| w[0] == w[1]);
    let s_bytes = (p.elems * 4) as f64;
    let wire = ring::wire_bytes_per_worker(s_bytes, p.world);
    let mean_ar = ar.iter().sum::<f64>() / p.steps as f64;
    let effective_bus_gbps = if wire > 0.0 && mean_ar > 0.0 {
        crate::bytes_per_sec_to_gbps(wire / mean_ar)
    } else {
        0.0
    };
    Ok(LaunchReport {
        workers: p.world,
        steps: p.steps,
        step_wall_s: step_wall,
        allreduce_s: ar,
        effective_bus_gbps,
        checksums,
        identical,
        knob_trajectory,
        breakdown,
        wire_mean_bps,
        util_timeline,
        detections,
    })
}

/// Read rank 0's post-done span shipment (`trace <len>` header then
/// exactly `len` encoded bytes). The socket keeps the collection loop's
/// short poll timeout, so both reads tolerate `WouldBlock`/`TimedOut`
/// under an overall deadline — generous, because the worker writes the
/// whole shipment immediately after its done line.
fn read_span_trace(reader: &mut BufReader<TcpStream>) -> Result<Vec<crate::obs::SpanRecord>> {
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut hdr = String::new();
    while !hdr.ends_with('\n') {
        match reader.read_line(&mut hdr) {
            Ok(0) => anyhow::bail!("rank 0 closed before sending its span trace"),
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e).context("read span trace header"),
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "rank 0 never sent its span trace (was the worker started with --obs?)"
        );
    }
    let len: usize = hdr
        .trim()
        .strip_prefix("trace ")
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad span trace header {hdr:?}"))?;
    let mut blob = vec![0u8; len];
    let mut got = 0usize;
    while got < len {
        match reader.read(&mut blob[got..]) {
            Ok(0) => anyhow::bail!("rank 0 closed mid span trace ({got} of {len} bytes)"),
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e).context("read span trace bytes"),
        }
        anyhow::ensure!(Instant::now() < deadline, "span trace stalled at {got} of {len} bytes");
    }
    crate::obs::span::decode(&blob)
}

/// Serialize/parse rank 0's chunk trajectory for the done line:
/// whitespace-free `step:chunk_kb;step:chunk_kb` pairs.
fn format_trajectory(traj: &[(u64, KnobPoint)]) -> String {
    if traj.is_empty() {
        return "-".to_string();
    }
    traj.iter()
        .map(|(step, p)| format!("{step}:{}", p.chunk_kb))
        .collect::<Vec<_>>()
        .join(";")
}

fn parse_trajectory(s: &str) -> Result<Vec<(u64, usize)>> {
    s.split(';')
        .filter(|part| !part.is_empty())
        .map(|part| {
            let (step, kb) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad trajectory entry {part:?}"))?;
            Ok((
                step.parse().map_err(|_| anyhow::anyhow!("bad trajectory step {step:?}"))?,
                kb.parse().map_err(|_| anyhow::anyhow!("bad trajectory chunk {kb:?}"))?,
            ))
        })
        .collect()
}

/// Sub-tag on [`tags::CONTROL`] carrying span snapshots (the autotune
/// knob broadcast uses sub 0, so the two control flows never collide).
const OBS_SUB: u32 = 1;
/// Buckets in the coordinator's link-utilization timeline.
const UTIL_TIMELINE_BINS: usize = 20;

/// One obs shipping round at a step boundary: the rank drains the spans
/// it recorded since the previous round (rank-filtered — thread-mode
/// launches share one process-global ring) and sends them to rank 0,
/// which merges the batches with its own.
fn ship_spans(
    ep: &dyn Endpoint,
    rank: usize,
    p: &WorkerParams,
    step: u32,
    cursor: &mut u64,
    merged: &mut Vec<crate::obs::SpanRecord>,
) -> Result<()> {
    use crate::obs::span;
    let ctrl = tag(tags::CONTROL, step, OBS_SUB);
    let (batch, next) = span::since(*cursor, Some(rank as u32));
    *cursor = next;
    if rank == 0 {
        merged.extend(batch);
        for w in 1..p.world {
            let raw = ep.recv_buf(WorkerId(w), ctrl)?;
            merged.extend(span::decode(&raw)?);
        }
    } else {
        ep.send(WorkerId(0), ctrl, &span::encode(&batch))?;
    }
    Ok(())
}

/// Serialize/parse rank 0's per-step breakdown for the done line:
/// whitespace-free `step:barrier:compute:serialize:wire:reduce:total`
/// tuples joined with `;`.
fn format_breakdown(b: &[crate::obs::StepBreakdown]) -> String {
    if b.is_empty() {
        return "-".to_string();
    }
    b.iter()
        .map(|x| {
            format!(
                "{}:{:.6}:{:.6}:{:.6}:{:.6}:{:.6}:{:.6}",
                x.step, x.barrier_s, x.compute_s, x.serialize_s, x.wire_s, x.reduce_s, x.total_s
            )
        })
        .collect::<Vec<_>>()
        .join(";")
}

fn parse_breakdown(s: &str) -> Result<Vec<crate::obs::StepBreakdown>> {
    s.split(';')
        .filter(|part| !part.is_empty())
        .map(|part| {
            let f: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(f.len() == 7, "bad breakdown entry {part:?}");
            let num = |i: usize| -> Result<f64> {
                f[i].parse().map_err(|_| anyhow::anyhow!("bad breakdown field {:?}", f[i]))
            };
            Ok(crate::obs::StepBreakdown {
                step: f[0].parse().map_err(|_| anyhow::anyhow!("bad breakdown step {:?}", f[0]))?,
                barrier_s: num(1)?,
                compute_s: num(2)?,
                serialize_s: num(3)?,
                wire_s: num(4)?,
                reduce_s: num(5)?,
                total_s: num(6)?,
            })
        })
        .collect()
}

/// Serialize/parse the utilization timeline: `t_seconds:bytes_per_sec`
/// pairs joined with `,`.
fn format_timeline(tl: &[(f64, f64)]) -> String {
    if tl.is_empty() {
        return "-".to_string();
    }
    tl.iter().map(|(t, bps)| format!("{t:.6}:{bps:.3}")).collect::<Vec<_>>().join(",")
}

fn parse_timeline(s: &str) -> Result<Vec<(f64, f64)>> {
    s.split(',')
        .filter(|part| !part.is_empty())
        .map(|part| {
            let (t, bps) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad timeline entry {part:?}"))?;
            Ok((
                t.parse().map_err(|_| anyhow::anyhow!("bad timeline time {t:?}"))?,
                bps.parse().map_err(|_| anyhow::anyhow!("bad timeline rate {bps:?}"))?,
            ))
        })
        .collect()
}

fn parse_csv_f64(s: &str, want: usize) -> Result<Vec<f64>> {
    let v: Vec<f64> = s
        .split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<f64>().map_err(|_| anyhow::anyhow!("bad timing {p:?}")))
        .collect::<Result<_>>()?;
    anyhow::ensure!(v.len() == want, "expected {want} timings, got {}", v.len());
    Ok(v)
}

/// One worker's whole life, process or thread: rendezvous, fabric, the
/// synchronous training loop, the completion report. This is what
/// `netbn _worker` calls.
pub fn worker_entry(rank: usize, coordinator: SocketAddr, p: &WorkerParams) -> Result<()> {
    anyhow::ensure!(rank < p.world, "rank {rank} out of a world of {}", p.world);
    // Observability: arm the tracer before any instrumented path runs.
    // The cursor snapshot keeps spans from earlier runs in the same
    // process (sequential thread-mode launches) out of this run's report.
    let obs_on = p.obs || p.trace_out.is_some();
    if obs_on {
        crate::obs::span::enable();
    }
    let mut obs_cursor = crate::obs::span::cursor();
    let mut obs_merged: Vec<crate::obs::SpanRecord> = Vec::new();
    let lanes = launch_lanes(p);
    // Rendezvous: connect the coordinator FIRST — the local address of
    // that connection is the interface that routes to it, and the lane
    // listeners bind there so a multi-host worker advertises reachable
    // addresses instead of its own loopback.
    let mut coord = connect_retry(coordinator, Duration::from_secs(10))
        .context("connect to coordinator")?;
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let lane_ip = coord.local_addr()?.ip();
    // One mesh listener per lane: `striped:K` really is K connections per
    // peer pair across process boundaries.
    let mut nodes = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        nodes.push(MeshNode::bind_on(lane_ip, WorkerId(rank), p.world)?);
    }
    // Register lane addresses, receive everyone's.
    let mut hello = format!("hello {rank}");
    for n in &nodes {
        hello.push(' ');
        hello.push_str(&n.addr().to_string());
    }
    hello.push('\n');
    coord.write_all(hello.as_bytes()).context("send hello")?;
    let mut reader = BufReader::new(coord.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line).context("read peer table")?;
    let mut it = line.split_whitespace();
    anyhow::ensure!(it.next() == Some("peers"), "bad peer table line {line:?}");
    let got_lanes: usize = it
        .next()
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("peer table missing lane count: {line:?}"))?;
    let got_world: usize = it
        .next()
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("peer table missing world size: {line:?}"))?;
    anyhow::ensure!(
        got_lanes == lanes && got_world == p.world,
        "peer table shape {got_world}x{got_lanes}, expected {}x{lanes}",
        p.world
    );
    let flat: Vec<SocketAddr> =
        it.map(|s| s.parse().context("bad peer address")).collect::<Result<_>>()?;
    anyhow::ensure!(flat.len() == p.world * lanes, "peer table truncated");
    // flat is rank-major: entry w*lanes + l. Keep the concrete mesh
    // handles: they own the recv deadline (the anti-wedge clock) and the
    // poison switch the error path below throws.
    let mut mesh_lanes: Vec<Arc<crate::net::mesh::MeshEndpoint>> = Vec::with_capacity(lanes);
    let mut lane_eps: Vec<Arc<dyn Endpoint>> = Vec::with_capacity(lanes);
    for (l, node) in nodes.into_iter().enumerate() {
        let addrs: Vec<SocketAddr> = (0..p.world).map(|w| flat[w * lanes + l]).collect();
        let mep = node.connect(addrs)?;
        mesh_lanes.push(Arc::clone(&mep));
        lane_eps.push(mep as Arc<dyn Endpoint>);
    }
    // Until a step time is measured, allow a generous bring-up deadline
    // (peers may still be spawning / connecting).
    for mep in &mesh_lanes {
        mep.set_recv_timeout(Some(Duration::from_secs(15)));
    }
    // Bind the lanes. The striped path keeps the concrete endpoint so the
    // control plane can retune its chunk size (and gate rate) mid-run.
    let (ep, striped): (Arc<dyn Endpoint>, Option<Arc<StripedEndpoint>>) = match p.transport {
        TransportKind::Striped { streams } => {
            let sep = launch_striped_transport(p, streams).bind_striped(lane_eps)?;
            (Arc::clone(&sep) as Arc<dyn Endpoint>, Some(sep))
        }
        _ => (SingleStream.bind(lane_eps)?, None),
    };

    // ---- Autotune bring-up: every rank deterministically derives the
    // same snapped starting point and applies it before any data flows;
    // rank 0 additionally owns the controller. ----
    let streams = match p.transport {
        TransportKind::Striped { streams } => streams,
        _ => 1,
    };
    let mut tuner: Option<AutoTuner> = None;
    if p.autotune {
        let sep = striped.as_ref().expect("validated: autotune requires a striped transport");
        let space = launch_knob_space(p, streams);
        let initial = launch_initial_point(p, streams);
        let start = space.point_at(space.nearest_index(&initial));
        sep.set_chunk_bytes(start.chunk_kb << 10)?;
        if rank == 0 {
            let cfg = TunerConfig {
                warmup_steps: 2,
                probe_steps: 2,
                hysteresis: 0.05,
                regress_threshold: 0.25,
                regress_patience: 3,
                max_passes: 2,
                seed: p.seed ^ 0x5EED_C4A0,
            };
            tuner = Some(AutoTuner::new(space, cfg, &initial)?);
        }
    }

    // ---- The synchronous data-parallel loop, driven by the overlap
    // scheduler: per-layer modeled compute (reverse order, like a real
    // backward pass), deterministic bucket plan, async collective engine.
    // Every rank derives the identical plan from the shared params, so
    // the per-bucket collectives stay matched. ----
    let ranges = layer_ranges(p.elems, p.layers);
    let plan = plan_buckets(&ready_order_from_ranges(&ranges), mb_to_threshold(p.bucket_mb));
    let layer_compute_s = p.compute_us as f64 * 1e-6 / p.layers as f64;
    let engine = AsyncCollectiveEngine::new(Arc::clone(&ep), p.collective);

    let mut params = vec![0.0f32; p.elems];
    let mut rng = Rng::new(p.seed ^ ((rank as u64) << 32));
    let mut ar_times = Vec::with_capacity(p.steps);
    let mut walls = Vec::with_capacity(p.steps);
    let inv_world = 1.0f32 / p.world as f32;
    // A knob decision exchanged at the end of step s is APPLIED only
    // after barrier(s+1): a rank enters that barrier only once it has
    // consumed every step-s stripe addressed to it, so barrier completion
    // proves every lane-sender queue has fully drained — the only moment
    // a chunk-layout change cannot race an in-flight message.
    let mut pending_knobs: Option<KnobPoint> = None;
    // The loop runs inside a closure so any failure — typically a recv
    // deadline naming a dead peer — can poison the remaining lanes and
    // report an `abort` line before propagating, instead of leaving the
    // coordinator and the surviving ranks to wedge.
    let step_loop = (|| -> Result<()> {
        for step in 0..p.steps {
            let total_sp = crate::span!("step.total", rank, step);
            {
                let _sp = crate::span!("step.barrier", rank, step);
                barrier(ep.as_ref(), step as u32)?;
            }
            if let Some(k) = pending_knobs.take() {
                if let Some(sep) = &striped {
                    sep.set_chunk_bytes(k.chunk_kb << 10)?;
                }
            }
            // Scripted NIC event: every rank drops its per-stream gate at the
            // same (barrier-aligned) step — the environment change the
            // autotune_adapt scenario recovers from. (Pacing only: gates need
            // no cross-rank layout agreement.)
            if p.drop_at_step > 0 && step == p.drop_at_step {
                if let Some(sep) = &striped {
                    sep.set_stream_rate_bytes_per_sec(crate::gbps_to_bytes_per_sec(p.drop_gbps))?;
                }
            }
            let t_step = Instant::now();
            // Local gradient: different on every rank (seeded), summed by the
            // collective — the data-parallel contract. Generated up front in
            // both overlap modes so the wire bytes are identical either way.
            let mut grad;
            {
                let _sp = crate::span!("step.grad", rank, step, (p.elems * 4) as u64);
                grad = vec![0.0f32; p.elems];
                rng.fill_f32(&mut grad, 1.0);
            }
            let stats = run_step(
                &engine,
                p.overlap,
                step as u32,
                &mut grad,
                &ranges,
                &plan,
                |_layer| super::spin_sleep(layer_compute_s),
            )?;
            // Comm-busy time of the engine's worker (includes any span
            // overlapped under compute) — keeps the effective-bus-bandwidth
            // figure comparable across overlap modes.
            ar_times.push(stats.comm_busy_s);
            // Averaged-gradient step: identical arithmetic on identical sums
            // keeps every rank's parameters bit-identical.
            {
                let _sp = crate::span!("step.update", rank, step);
                for (w, g) in params.iter_mut().zip(&grad) {
                    *w -= 0.05 * g * inv_world;
                }
            }
            drop(total_sp);
            walls.push(t_step.elapsed().as_secs_f64());

            // Anti-wedge clock: re-derive the recv deadline from recent
            // step times, so the "dead peer" verdict tracks the actual
            // pace of this run (fast runs fail fast; a slow modeled-
            // compute run never false-positives). 25x the worst recent
            // wall leaves room for the scripted mid-run NIC drops.
            let recent = walls.iter().rev().take(3).fold(0.0f64, |a, w| a.max(*w));
            let d = Duration::from_secs_f64((recent * 25.0).max(0.9))
                + Duration::from_millis(100);
            for mep in &mesh_lanes {
                mep.set_recv_timeout(Some(d));
            }

            // ---- The control round: rank 0 feeds the tuner and broadcasts
            // the decision over the mesh control channel; every rank applies
            // it here — after all of this step's collectives drained and
            // before the next barrier, so sender and receiver chunk layouts
            // can never disagree mid-message. ----
            if p.autotune {
                let ctrl = tag(tags::CONTROL, step as u32, 0);
                if rank == 0 {
                    let wall = *walls.last().expect("pushed above");
                    let fb =
                        step_feedback(p, step as u64, wall, stats.compute_s, stats.comm_busy_s);
                    let decision = tuner.as_mut().expect("rank 0 owns the tuner").observe(&fb);
                    let msg = match &decision {
                        Some(next) => next.spec(),
                        None => "keep".to_string(),
                    };
                    for w in 1..p.world {
                        ep.send(WorkerId(w), ctrl, msg.as_bytes())?;
                    }
                    pending_knobs = decision;
                } else {
                    let raw = ep.recv_buf(WorkerId(0), ctrl)?;
                    let msg = std::str::from_utf8(&raw)
                        .map_err(|_| anyhow::anyhow!("knob broadcast is not UTF-8"))?;
                    if msg != "keep" {
                        pending_knobs = Some(KnobPoint::parse_spec(&msg)?);
                    }
                }
            }

            // ---- Obs shipping: each rank drains the spans it recorded
            // since the last boundary and sends them to rank 0. Runs after
            // the step's collectives drained, so the control traffic never
            // contends with gradient stripes. ----
            if obs_on {
                ship_spans(ep.as_ref(), rank, p, step as u32, &mut obs_cursor, &mut obs_merged)?;
            }
        }
        // Lane senders close their wire.send spans asynchronously (send()
        // returns once the job is enqueued) — give the final step's
        // laggards a beat, then flush the remainder in one last round.
        if obs_on {
            std::thread::sleep(Duration::from_millis(5));
            ship_spans(ep.as_ref(), rank, p, p.steps as u32, &mut obs_cursor, &mut obs_merged)?;
        }
        Ok(())
    })();
    if let Err(e) = step_loop {
        let reason = format!("{e:#}").replace('\n', " ");
        for mep in &mesh_lanes {
            mep.poison(format!("rank {rank} aborted: {reason}"));
        }
        let _ = writeln!(coord, "abort {rank} {reason}");
        return Err(e);
    }
    drop(engine);
    let checksum = tensor_checksum(&params);

    // Rank 0 turns the merged span stream into the run's observability
    // aggregates: align the per-rank clocks on the step-0 barrier, then
    // derive the per-step breakdown, the delivered wire rate and the
    // utilization timeline, and export the Chrome trace if asked.
    let mut obs_fields = ("-".to_string(), "-".to_string(), "-".to_string());
    if obs_on && rank == 0 {
        crate::obs::breakdown::align(&mut obs_merged, "step.barrier");
        let breakdown = crate::obs::breakdown::per_step(&obs_merged);
        let wire_bps = crate::obs::breakdown::wire_mean_bps(&obs_merged);
        let timeline = crate::obs::breakdown::util_timeline(&obs_merged, UTIL_TIMELINE_BINS);
        if let Some(path) = &p.trace_out {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(path, crate::obs::span::chrome_trace_json(&obs_merged))
                .with_context(|| format!("write chrome trace to {}", path.display()))?;
        }
        obs_fields =
            (format_breakdown(&breakdown), format!("{wire_bps:.3}"), format_timeline(&timeline));
    }

    // Rank 0 replays its per-step busbw series through the same online
    // detector the serve daemon and `netbn bench --trend` run — a
    // mid-run rate drop becomes a Detection in the LaunchReport, not
    // just a slower row in the step table. Independent of --obs: the
    // inputs are the step timings every run already has.
    let mut det_field = "-".to_string();
    if rank == 0 {
        let series: Vec<(u64, f64)> = walls
            .iter()
            .zip(&ar_times)
            .enumerate()
            .map(|(s, (wall, busy))| {
                (s as u64, step_feedback(p, s as u64, *wall, (*wall - *busy).max(0.0), *busy).busbw_gbps)
            })
            .collect();
        let dets = crate::obs::detect::scan(
            crate::obs::detect::DetectorConfig::throughput(),
            crate::obs::detect::DetectionKind::ThroughputRegression,
            "busbw_gbps",
            &series,
        );
        det_field = crate::obs::detect::format_detections(&dets);
    }

    // Report and wait for the global release before tearing down lanes.
    let mut done = format!("done {rank} {checksum:x} ");
    done.push_str(&join_csv(&ar_times));
    done.push(' ');
    done.push_str(&join_csv(&walls));
    done.push(' ');
    match &tuner {
        Some(t) => {
            // A decision exchanged at the final step's control round was
            // never applied (there is no next barrier): report only the
            // points that genuinely ran.
            let applied: Vec<(u64, KnobPoint)> = t
                .trajectory()
                .iter()
                .filter(|(step, _)| *step < p.steps as u64)
                .copied()
                .collect();
            done.push_str(&format_trajectory(&applied));
        }
        None => done.push('-'),
    }
    // Obs aggregates + detections, rank 0 only ("-" placeholders otherwise).
    for f in [&obs_fields.0, &obs_fields.1, &obs_fields.2, &det_field] {
        done.push(' ');
        done.push_str(f);
    }
    done.push('\n');
    // The release only arrives once the SLOWEST worker reports done, an
    // unbounded wait for fast ranks — no read timeout here; a dead
    // coordinator surfaces as EOF.
    coord.set_read_timeout(None).ok();
    coord.write_all(done.as_bytes()).context("send done")?;
    // Obs runs follow the done line with the merged (aligned) span
    // stream: `trace <len>` then exact bytes. Rank 0 may be a remote
    // external worker, so this is what lets the coordinator write
    // `--trace-out` on its own filesystem.
    if obs_on && rank == 0 {
        let blob = crate::obs::span::encode(&obs_merged);
        let mut msg = format!("trace {}\n", blob.len()).into_bytes();
        msg.extend_from_slice(&blob);
        coord.write_all(&msg).context("send span trace")?;
    }
    let mut bye = String::new();
    reader.read_line(&mut bye).context("read release")?;
    anyhow::ensure!(bye.trim() == "bye", "bad release line {bye:?}");
    Ok(())
}

fn join_csv(xs: &[f64]) -> String {
    xs.iter().map(|x| format!("{x:.9}")).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn thread_cfg(world: usize, collective: CollectiveKind, transport: TransportKind) -> LaunchConfig {
        LaunchConfig {
            params: WorkerParams {
                world,
                steps: 2,
                elems: 20_000,
                transport,
                collective,
                overlap: OverlapMode::Off,
                bucket_mb: 0.0,
                layers: 1,
                compute_us: 0,
                autotune: false,
                chunk_kbs: Vec::new(),
                gate_gbps: 0.0,
                drop_at_step: 0,
                drop_gbps: 0.0,
                seed: 0xe2e,
                obs: false,
                trace_out: None,
            },
            spawn: SpawnMode::Thread,
            feedback_out: None,
            rendezvous_timeout: Duration::from_secs(60),
            bind: loopback_bind(),
        }
    }

    #[test]
    fn launch_ring_over_single_stream() {
        let r = launch(&thread_cfg(3, CollectiveKind::Ring, TransportKind::Tcp)).unwrap();
        assert_eq!(r.workers, 3);
        assert_eq!(r.steps, 2);
        assert!(r.identical, "checksums {:?}", r.checksums);
        assert!(r.effective_bus_gbps > 0.0);
        assert!(r.passed());
        assert_eq!(r.step_wall_s.len(), 2);
        assert!(r.step_wall_s.iter().all(|t| *t > 0.0));
        assert!(r.allreduce_s.iter().all(|t| *t > 0.0));
    }

    #[test]
    fn launch_hier_over_striped() {
        // The tentpole combination: leader-ring collective over striped
        // lanes, real sockets between workers.
        let r = launch(&thread_cfg(
            4,
            CollectiveKind::Hierarchical { group_size: 2 },
            TransportKind::Striped { streams: 2 },
        ))
        .unwrap();
        assert!(r.identical, "checksums {:?}", r.checksums);
        assert!(r.effective_bus_gbps > 0.0);
        assert!(r.passed());
    }

    #[test]
    fn launch_deterministic_checksum_across_runs() {
        // Same seed, same world -> the same final bits, run to run; and
        // ring vs hier agree within tolerance but need not be bit-equal
        // (different summation order).
        let a = launch(&thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp)).unwrap();
        let b = launch(&thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp)).unwrap();
        assert_eq!(a.checksums, b.checksums);
    }

    #[test]
    fn launch_single_worker_degenerates() {
        let r = launch(&thread_cfg(1, CollectiveKind::Ring, TransportKind::Tcp)).unwrap();
        assert!(r.identical);
        assert_eq!(r.effective_bus_gbps, 0.0);
        assert!(r.passed());
    }

    #[test]
    fn launch_rejects_degenerate_configs() {
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.steps = 0;
        assert!(launch(&cfg).is_err());
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.elems = 0;
        assert!(launch(&cfg).is_err());
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.layers = 0;
        assert!(launch(&cfg).is_err());
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.layers = cfg.params.elems + 1;
        assert!(launch(&cfg).is_err());
    }

    #[test]
    fn overlap_modes_are_bit_identical_end_to_end() {
        // The overlap conformance contract at the launch level: same
        // seeds, same bucket plan, different submission policy — the
        // final parameter checksums must agree bit for bit.
        let mut base = thread_cfg(3, CollectiveKind::Ring, TransportKind::Tcp);
        base.params.layers = 6;
        base.params.bucket_mb = 0.02; // ~5 KB buckets over an 80 KB tensor
        base.params.compute_us = 2_000;
        let mut overlapped = base.clone();
        overlapped.params.overlap = OverlapMode::Buckets;
        let a = launch(&base).unwrap();
        let b = launch(&overlapped).unwrap();
        assert!(a.identical && b.identical);
        assert_eq!(a.checksums, b.checksums, "overlap changed the arithmetic");
        assert!(b.effective_bus_gbps > 0.0);
    }

    #[test]
    fn bucketized_hier_over_striped_launch() {
        // Everything at once: leader-ring collective, striped lanes,
        // DDP-style buckets, overlapped submission — over real sockets.
        let mut cfg = thread_cfg(
            4,
            CollectiveKind::Hierarchical { group_size: 2 },
            TransportKind::Striped { streams: 2 },
        );
        cfg.params.overlap = OverlapMode::Buckets;
        cfg.params.layers = 5;
        cfg.params.bucket_mb = 0.03;
        let r = launch(&cfg).unwrap();
        assert!(r.identical, "checksums {:?}", r.checksums);
        assert!(r.passed());
    }

    #[test]
    fn autotuned_launch_is_bit_identical_to_static() {
        // The control plane's safety gate: same seeds, knob broadcasts
        // retuning the chunk size mid-run — and the final parameter bits
        // must equal the static run's exactly, rank for rank.
        let static_cfg =
            thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        let mut tuned = static_cfg.clone();
        tuned.params.autotune = true;
        tuned.params.chunk_kbs = vec![4, 16, 64];
        tuned.params.steps = 8;
        let mut static_long = static_cfg.clone();
        static_long.params.steps = 8;
        let a = launch(&static_long).unwrap();
        let b = launch(&tuned).unwrap();
        assert!(a.identical && b.identical);
        assert_eq!(a.checksums, b.checksums, "autotuning changed the arithmetic");
        // The tuner genuinely ran: rank 0 reported a trajectory whose
        // first entry is the snapped starting chunk.
        assert!(!b.knob_trajectory.is_empty());
        assert!(a.knob_trajectory.is_empty());
        // 8 steps = 2 warmup + 3 candidates × 2 probe steps: the probe
        // visited at least one non-initial chunk size.
        assert!(b.knob_trajectory.len() >= 2, "{:?}", b.knob_trajectory);
        for (_, kb) in &b.knob_trajectory {
            assert!(tuned.params.chunk_kbs.contains(kb), "{kb} not a candidate");
        }
    }

    #[test]
    fn gated_launch_with_mid_run_drop_completes() {
        // The adapt scenario's mechanism in miniature: a per-stream gate
        // drops 10x mid-run; the run completes, stays bit-identical, and
        // the post-drop steps are visibly slower.
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        cfg.params.elems = 60_000;
        cfg.params.steps = 6;
        cfg.params.gate_gbps = 0.5;
        cfg.params.drop_at_step = 3;
        cfg.params.drop_gbps = 0.05;
        let r = launch(&cfg).unwrap();
        assert!(r.identical);
        assert!(r.passed());
        let pre = r.step_wall_s[1].min(r.step_wall_s[2]);
        let post = r.step_wall_s[4].max(r.step_wall_s[5]);
        assert!(post > pre * 2.0, "drop not visible: pre {pre} post {post}");
        // The online detector flags the collapse within 3 steps of the
        // scripted drop, and never before it.
        assert!(!r.detections.is_empty(), "drop must be detected");
        for d in &r.detections {
            assert!(d.at >= 3 && d.at <= 6, "detection outside the drop window: {d:?}");
            assert!(d.z < 0.0, "throughput collapse must be a low-side anomaly: {d:?}");
        }
    }

    #[test]
    fn steady_launch_reports_no_detections() {
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        cfg.params.elems = 60_000;
        cfg.params.steps = 6;
        cfg.params.gate_gbps = 0.5;
        let r = launch(&cfg).unwrap();
        assert!(r.passed());
        assert!(r.detections.is_empty(), "false positives on a steady run: {:?}", r.detections);
    }

    #[test]
    fn feedback_out_writes_replayable_records() {
        let path = std::env::temp_dir().join("netbn_launch_feedback_test.jsonl");
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.steps = 3;
        cfg.feedback_out = Some(path.clone());
        let r = launch(&cfg).unwrap();
        assert!(r.passed());
        let recs = crate::measure::trace::load_step_feedback(&path).unwrap();
        assert_eq!(recs.len(), 3);
        for (s, rec) in recs.iter().enumerate() {
            assert_eq!(rec.step as usize, s);
            assert!(rec.wall_s > 0.0);
            assert!(rec.busbw_gbps > 0.0);
        }
    }

    #[test]
    fn autotune_validation_requires_striped() {
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.params.autotune = true;
        cfg.params.chunk_kbs = vec![32];
        assert!(launch(&cfg).is_err());
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        cfg.params.autotune = true;
        assert!(launch(&cfg).is_err(), "empty chunk axis must be rejected");
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        cfg.params.drop_at_step = 1;
        assert!(launch(&cfg).is_err(), "drop without a gate must be rejected");
    }

    #[test]
    fn trajectory_wire_format_round_trips() {
        let p = |kb: usize| KnobPoint { chunk_kb: kb, ..KnobPoint::default_static() };
        let traj = vec![(0u64, p(32)), (6u64, p(4))];
        let s = format_trajectory(&traj);
        assert!(!s.contains(' '), "done-line fields are whitespace-delimited");
        assert_eq!(parse_trajectory(&s).unwrap(), vec![(0, 32), (6, 4)]);
        assert_eq!(format_trajectory(&[]), "-");
        assert!(parse_trajectory("3:x").is_err());
    }

    #[test]
    fn obs_wire_formats_round_trip() {
        let b = vec![
            crate::obs::StepBreakdown {
                step: 0,
                barrier_s: 0.001,
                compute_s: 0.0205,
                serialize_s: 0.0003,
                wire_s: 0.04,
                reduce_s: 0.01,
                total_s: 0.0725,
            },
            crate::obs::StepBreakdown { step: 1, ..Default::default() },
        ];
        let s = format_breakdown(&b);
        assert!(!s.contains(' '), "done-line fields are whitespace-delimited");
        let back = parse_breakdown(&s).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].step, 0);
        assert!((back[0].wire_s - 0.04).abs() < 1e-9);
        assert!((back[0].components_sum() - b[0].components_sum()).abs() < 1e-5);
        assert_eq!(format_breakdown(&[]), "-");
        assert!(parse_breakdown("0:1:2").is_err());

        let tl = vec![(0.005, 1.25e8), (0.015, 0.0)];
        let s = format_timeline(&tl);
        assert!(!s.contains(' '));
        let back = parse_timeline(&s).unwrap();
        assert_eq!(back.len(), 2);
        assert!((back[0].0 - 0.005).abs() < 1e-9);
        assert!((back[0].1 - 1.25e8).abs() < 1.0);
        assert_eq!(format_timeline(&[]), "-");
        assert!(parse_timeline("1:x").is_err());
    }

    #[test]
    fn obs_launch_reports_breakdown_and_writes_trace() {
        // Serialize with the other tracer-enabling tests: the ring is
        // process-global and this test flips the tracer on.
        let _serial = crate::obs::span::test_lock();
        let trace = std::env::temp_dir().join("netbn_launch_obs_test_trace.json");
        let _ = std::fs::remove_file(&trace);
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Striped { streams: 2 });
        cfg.params.obs = true;
        cfg.params.trace_out = Some(trace.clone());
        cfg.params.steps = 3;
        let r = launch(&cfg).unwrap();
        crate::obs::span::disable();
        assert!(r.passed());
        // Soft assertions only: other tests in this process may record
        // spans concurrently while the tracer is on, so the aggregates
        // must be present and sane, not exact. The strict utilization /
        // breakdown-gap checks run in the isolated `utilization_timeline`
        // scenario binary.
        assert!(!r.breakdown.is_empty(), "obs run produced no breakdown");
        assert!(r.breakdown.iter().all(|b| b.total_s > 0.0), "{:?}", r.breakdown);
        assert!(r.breakdown.iter().all(|b| b.components_sum() > 0.0), "{:?}", r.breakdown);
        assert!(r.wire_mean_bps > 0.0, "striped run moved bytes on the wire");
        assert!(!r.util_timeline.is_empty());
        let json = std::fs::read_to_string(&trace).unwrap();
        assert!(json.contains("\"traceEvents\""), "{json}");
        assert!(json.contains("wire.send"), "{json}");
        let _ = std::fs::remove_file(&trace);
    }

    #[test]
    fn non_obs_launch_reports_empty_aggregates() {
        let r = launch(&thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp)).unwrap();
        assert!(r.breakdown.is_empty());
        assert_eq!(r.wire_mean_bps, 0.0);
        assert!(r.util_timeline.is_empty());
    }

    #[test]
    fn spawn_mode_parse() {
        assert_eq!(SpawnMode::parse("process"), Some(SpawnMode::Process));
        assert_eq!(SpawnMode::parse("Thread"), Some(SpawnMode::Thread));
        assert_eq!(SpawnMode::parse("external"), Some(SpawnMode::External));
        assert_eq!(SpawnMode::parse("fork"), None);
    }

    #[test]
    fn rendezvous_timeout_is_validated_and_enforced() {
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.rendezvous_timeout = Duration::ZERO;
        assert!(launch(&cfg).is_err(), "zero rendezvous timeout must be rejected");

        // External mode spawns nothing: with no worker ever dialing in,
        // the coordinator must give up at the configured deadline — fast —
        // instead of the old hardwired 60 s.
        let mut cfg = thread_cfg(2, CollectiveKind::Ring, TransportKind::Tcp);
        cfg.spawn = SpawnMode::External;
        cfg.rendezvous_timeout = Duration::from_millis(300);
        let t0 = Instant::now();
        let err = launch(&cfg).unwrap_err().to_string();
        assert!(err.contains("rendezvous"), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timeout not honored: took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn checksum_is_bit_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        b[1] = f32::from_bits(b[1].to_bits() ^ 1);
        assert_ne!(tensor_checksum(&a), tensor_checksum(&b));
        assert_eq!(tensor_checksum(&a), tensor_checksum(&a));
    }

    #[test]
    fn csv_round_trip() {
        let xs = vec![0.001, 2.5, 0.0];
        assert_eq!(parse_csv_f64(&join_csv(&xs), 3).unwrap(), xs);
        assert!(parse_csv_f64("1,2", 3).is_err());
        assert!(parse_csv_f64("1,x,3", 3).is_err());
    }
}
