//! The data-parallel trainer.
//!
//! Two engines share the same communication machinery (bucketizer →
//! [`crate::sched::AsyncCollectiveEngine`] → all-reduce over a
//! [`crate::net::Fabric`]; `--overlap off|buckets` decides whether
//! buckets enter the engine as backward emits them or only after
//! backward ends, and `--bucket-mb` swaps the Horovod fusion buffer for
//! the DDP-style size-threshold bucketizer):
//!
//! * [`run_emulated`] — **modeled compute**: each worker replays the
//!   device timing trace (sleeping through forward/backward and emitting
//!   gradient tensors at the recorded instants) while the communication
//!   phase moves *real bytes* through the shaped fabric. This is the
//!   measurement bed for scaling-factor experiments on a 1-core host: the
//!   sleeps release the CPU, so communication genuinely overlaps backward,
//!   exactly like the GPU/NIC concurrency it stands in for.
//! * [`xla::XlaTrainer`] — **real compute**: executes the AOT train-step
//!   artifact through the PJRT device service (the e2e example).
//!
//! Payload scaling: emulated runs shrink gradient *bytes* and NIC *rate*
//! by the same factor `payload_scale`, leaving every time ratio intact
//! while fitting hundreds of MB of model on loopback.
//!
//! A third engine, [`launch`], drops the emulation entirely: `netbn
//! launch` spawns real worker *processes* on loopback TCP (rendezvous
//! via a coordinator port) and runs synchronous data-parallel steps over
//! the striped transport end to end.
//!
//! A fourth, [`elastic`], makes that multi-process path fault-tolerant:
//! membership epochs with deterministic re-sharding over a fixed logical
//! shard count, checkpoint/rollback replay of a crashed worker's shards,
//! and straggler scoring from the same [`crate::tune::StepFeedback`]
//! stream — the bits of the final tensor stay identical through joins,
//! leaves and kill -9.

pub mod elastic;
pub mod launch;
pub mod xla;

use crate::collectives::barrier;
use crate::collectives::fusion::{FusionBuffer, GradTensor};
use crate::config::{ExperimentConfig, OverlapMode, TransportKind};
use crate::measure::PhaseTimes;
use crate::models::timing::{backward_trace, StepTrace};
use crate::net::kernel_tcp::KernelTcpModel;
use crate::net::metrics::UtilizationSampler;
use crate::net::shaper::Shaper;
use crate::net::{inproc::InProcFabric, Endpoint, Fabric};
use crate::sched::{AllReduceHandle, AsyncCollectiveEngine, TimelineCache};
use crate::topology::Topology;
use crate::tune::{AutoTuner, KnobPoint, KnobSpace, StepFeedback, TunerConfig, TuningSummary};
use crate::util::Rng;
use crate::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Emulated-run configuration on top of the experiment point.
#[derive(Clone, Debug)]
pub struct EmulatedRunConfig {
    pub exp: ExperimentConfig,
    /// Divide gradient bytes and NIC rate by this factor (time-neutral).
    pub payload_scale: f64,
}

impl EmulatedRunConfig {
    pub fn new(exp: ExperimentConfig) -> EmulatedRunConfig {
        // Default scale keeps per-step wire traffic in the tens of MB.
        EmulatedRunConfig { exp, payload_scale: 64.0 }
    }
}

/// Result of an emulated or real run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Mean wall time per training step (measured window).
    pub step_time_s: f64,
    /// Samples (images/sequences) per second across the cluster.
    pub throughput: f64,
    /// `T_n / (n · T)` against the single-device baseline step time.
    pub scaling_factor: f64,
    pub mean_compute_s: f64,
    pub mean_comm_wait_s: f64,
    /// Mean provisioned-bandwidth utilization over the run (Fig 4's y).
    pub network_utilization: f64,
    /// Buckets all-reduced per step (mean).
    pub buckets_per_step: f64,
    pub steps: usize,
    pub workers: usize,
    /// Worker 0's tuning decisions when `--autotune` was on.
    pub autotune: Option<TuningSummary>,
}

/// Shared per-run tuning state: worker 0 writes the knob decision at the
/// end of a step; every worker reads it right after the next step's
/// barrier — the barrier orders the write before every read, so all
/// ranks derive the identical bucket timeline and stay matched.
struct EmuTuning {
    current: Mutex<KnobPoint>,
    cache: TimelineCache,
}

/// The axes the emulator can retune per step (bucket threshold and
/// compression); the rest are frozen at the config's values because the
/// fabric and the collective engine are built once per run. The
/// experiment's OWN bucket threshold and compression always join the
/// candidate sets: the configured operating point must be exactly
/// representable, so the run starts on what the user asked for and only
/// moves away when a candidate measures better.
fn emu_knob_space(exp: &ExperimentConfig) -> KnobSpace {
    let stripes = match exp.transport {
        TransportKind::Striped { streams } => streams,
        _ => 1,
    };
    // `bucket_mb == 0` is a real candidate value: it selects the
    // fusion-buffer timeline (the worker's per-step knob read falls back
    // to the precomputed default timeline for it), so a `bucket_mb = 0`
    // config genuinely starts on its own fused schedule.
    let configured_bucket = exp.bucket_mb.max(0.0);
    let mut bucket_mbs = exp.autotune.bucket_mbs.clone();
    if !bucket_mbs.contains(&configured_bucket) {
        bucket_mbs.push(configured_bucket);
    }
    let mut compressions = exp.autotune.compressions.clone();
    if !compressions.contains(&exp.compression) {
        compressions.push(exp.compression);
    }
    KnobSpace {
        bucket_mbs,
        stripes: vec![stripes],
        chunk_kbs: vec![256],
        collectives: vec![exp.collective],
        compressions,
    }
}

/// The config's own operating point, as a knob point (snapped onto the
/// space by the tuner).
fn emu_initial_point(exp: &ExperimentConfig) -> KnobPoint {
    let stripes = match exp.transport {
        TransportKind::Striped { streams } => streams,
        _ => 1,
    };
    KnobPoint {
        bucket_mb: exp.bucket_mb.max(0.0),
        stripes,
        chunk_kb: 256,
        collective: exp.collective,
        compression: exp.compression,
    }
}

fn emu_tuner_config(exp: &ExperimentConfig) -> TunerConfig {
    TunerConfig {
        warmup_steps: exp.warmup_steps.max(1),
        seed: exp.seed ^ 0xA070_70DE,
        ..TunerConfig::default()
    }
}

/// Precomputed deterministic bucket schedule: `(emit time rel. backward
/// start, bucket bytes)`.
///
/// Fusion decisions MUST be identical on every worker or the collectives
/// deadlock (Horovod solves this with a negotiation round; we solve it by
/// deriving the schedule from the shared trace in *virtual* time — the
/// same pass the what-if simulator runs — and replaying it in real time).
pub fn bucket_timeline(
    trace: &StepTrace,
    fusion_cfg: crate::config::FusionConfig,
) -> Vec<(f64, usize)> {
    let mut fusion = FusionBuffer::new(fusion_cfg);
    let mut out = Vec::new();
    for ev in &trace.events {
        let t = ev.t_ready;
        while let Some(d) = fusion.deadline() {
            if d < t {
                if let Some(b) = fusion.poll(d) {
                    out.push((d, b.bytes));
                }
            } else {
                break;
            }
        }
        for b in fusion.push(GradTensor::sized(ev.layer, ev.bytes), t) {
            out.push((t, b.bytes));
        }
    }
    while let Some(d) = fusion.deadline() {
        if d < trace.t_backward {
            if let Some(b) = fusion.poll(d) {
                out.push((d, b.bytes));
            }
        } else {
            break;
        }
    }
    if let Some(b) = fusion.flush() {
        out.push((trace.t_backward, b.bytes));
    }
    out
}

/// Run an emulated data-parallel training experiment.
pub fn run_emulated(cfg: &EmulatedRunConfig) -> Result<RunReport> {
    cfg.exp.validate().map_err(|e| anyhow::anyhow!("invalid config: {}", e.join("; ")))?;
    let exp = &cfg.exp;
    let topo = Topology::new(exp.servers, exp.gpus_per_server);
    let workers = topo.workers();
    let profile = exp.model.profile();
    let trace = backward_trace(&profile);

    // Transport: map the configured kind onto a shaped in-proc fabric.
    // (inproc, not TCP, for the figure-mode emulator: the fabric itself
    // must not add 1-core scheduling noise; TCP is exercised by the e2e
    // example and the integration tests.)
    let transport_model = match exp.transport {
        TransportKind::FullUtilization => KernelTcpModel::ideal(),
        TransportKind::KernelTcp => KernelTcpModel::default(),
        TransportKind::Tcp => KernelTcpModel::ideal(),
        TransportKind::Striped { streams } => {
            crate::net::striped::StripedModel::with_streams(streams).to_kernel_model()
        }
    };
    let latency = transport_model.per_msg_overhead_s;
    // Single-stream kinds shape the whole fabric at the model's effective
    // rate. The striped kind is mechanistic: the NIC is shaped at the
    // *provisioned* rate and the software ceiling moves into per-stream
    // gates inside the striped transport — N pipelines drain one NIC,
    // exactly the repair the simulator's `striped_like` models.
    let rate = match exp.transport {
        TransportKind::Striped { .. } => {
            crate::gbps_to_bytes_per_sec(exp.bandwidth_gbps) / cfg.payload_scale
        }
        _ => {
            crate::gbps_to_bytes_per_sec(transport_model.effective_gbps(exp.bandwidth_gbps))
                / cfg.payload_scale
        }
    };
    let shaper = Arc::new(Shaper::new(topo, rate, latency));
    let counters = shaper.counters();
    let fabric: Box<dyn Fabric> = match exp.transport {
        TransportKind::Striped { streams } => {
            let stripe_cfg = crate::net::striped::StripeConfig::with_streams(streams)
                .scaled(cfg.payload_scale);
            let per_stream_rate =
                crate::gbps_to_bytes_per_sec(KernelTcpModel::default().ceiling_gbps)
                    / cfg.payload_scale;
            let transport = crate::net::striped::StripedTransport::with_stream_ceiling(
                stripe_cfg,
                per_stream_rate,
            );
            Box::new(crate::net::transport::TransportFabric::inproc(
                workers,
                &transport,
                Some(Arc::clone(&shaper)),
            )?)
        }
        _ => Box::new(InProcFabric::with_shaper(workers, Some(Arc::clone(&shaper)))),
    };
    let endpoints = fabric.endpoints();

    let steps_total = exp.warmup_steps + exp.steps;
    // The striped transport is still the same software stack (hooks,
    // negotiation): only its ceiling changes.
    let software_stack =
        matches!(exp.transport, TransportKind::KernelTcp | TransportKind::Striped { .. });
    let compute_inflation = if software_stack { 1.12 } else { 1.0 };
    let coord_latency = if software_stack { 2.0e-3 } else { 0.0 };
    let bucket_count = Arc::new(AtomicU64::new(0));

    // Deterministic bucket schedule shared by every worker (this is what
    // keeps the collectives matched): the Horovod fusion buffer by
    // default, or the DDP-style size-threshold bucketizer when
    // `--bucket-mb` is set.
    let timeline = Arc::new(if exp.bucket_mb > 0.0 {
        crate::sched::bucket::bucket_timeline_from_trace(
            &trace,
            crate::sched::bucket::mb_to_threshold(exp.bucket_mb),
        )
    } else {
        bucket_timeline(&trace, exp.fusion)
    });

    // Autotune: shared knob cell + timeline cache. The starting point is
    // the config's own operating point snapped onto the knob grid — the
    // same snap the tuner performs, so worker 0's controller and the
    // shared cell agree from step 0.
    let tuning: Option<Arc<EmuTuning>> = if exp.autotune.enabled {
        let space = emu_knob_space(exp);
        space.validate().map_err(|e| anyhow::anyhow!("invalid autotune space: {e:#}"))?;
        let start = space.point_at(space.nearest_index(&emu_initial_point(exp)));
        Some(Arc::new(EmuTuning {
            current: Mutex::new(start),
            cache: TimelineCache::new(trace.clone()),
        }))
    } else {
        None
    };

    let mut handles = Vec::new();
    for ep in endpoints {
        let trace = trace.clone();
        let payload_scale = cfg.payload_scale;
        let bucket_count = Arc::clone(&bucket_count);
        let timeline = Arc::clone(&timeline);
        let tuning = tuning.clone();
        let exp = exp.clone();
        handles.push(std::thread::spawn(move || {
            worker_main(
                ep,
                &exp,
                trace,
                timeline,
                tuning,
                payload_scale,
                steps_total,
                compute_inflation,
                coord_latency,
                bucket_count,
            )
        }));
    }

    // Utilization sampling happens from the coordinator thread.
    let mut sampler = UtilizationSampler::new(&counters);
    let provisioned = crate::gbps_to_bytes_per_sec(exp.bandwidth_gbps) / cfg.payload_scale;
    let mut util_samples = Vec::new();
    let poll = Duration::from_millis(50);
    let mut pending: Vec<_> = handles.into_iter().collect();
    while pending.iter().any(|h| !h.is_finished()) {
        std::thread::sleep(poll);
        let s = sampler.sample(&counters);
        util_samples.push(s.mean_utilization(provisioned));
    }
    let mut phases = Vec::new();
    for h in pending.drain(..) {
        phases.push(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
    }
    // Worker 0 (spawn order = endpoint order) owns the tuner.
    let autotune_summary = phases.get_mut(0).and_then(|p| p.tuning.take());

    // Aggregate: all workers ran the same number of steps in lockstep; the
    // slowest worker's wall time defines the cluster step time.
    let step_time = phases.iter().map(|p| p.measured_wall_s).fold(0.0f64, f64::max)
        / exp.steps.max(1) as f64;
    let mean_compute =
        phases.iter().map(|p| p.phase.mean_compute()).sum::<f64>() / workers as f64;
    let mean_comm = phases.iter().map(|p| p.phase.mean_comm()).sum::<f64>() / workers as f64;
    let throughput = workers as f64 * exp.batch_per_worker as f64 / step_time;
    // Single-device baseline: modeled t_batch (uninflated) at the same
    // batch size.
    let base_throughput = exp.batch_per_worker as f64 / trace.t_batch;
    let scaling_factor = throughput / (workers as f64 * base_throughput);
    // Communication-active utilization: mean of nonzero samples.
    let active: Vec<f64> = util_samples.iter().copied().filter(|u| *u > 1e-6).collect();
    let network_utilization = if active.is_empty() {
        0.0
    } else {
        active.iter().sum::<f64>() / active.len() as f64
    };
    Ok(RunReport {
        step_time_s: step_time,
        throughput,
        scaling_factor,
        mean_compute_s: mean_compute,
        mean_comm_wait_s: mean_comm,
        network_utilization,
        buckets_per_step: bucket_count.load(Ordering::Relaxed) as f64
            / (workers as f64 * steps_total as f64),
        steps: exp.steps,
        workers,
        autotune: autotune_summary,
    })
}

struct WorkerOutcome {
    phase: PhaseTimes,
    /// Wall seconds spent in the measured (post-warmup) window.
    measured_wall_s: f64,
    /// Worker 0's tuner summary when autotuning.
    tuning: Option<TuningSummary>,
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    ep: Arc<dyn Endpoint>,
    exp: &ExperimentConfig,
    trace: StepTrace,
    timeline: Arc<Vec<(f64, usize)>>,
    tuning: Option<Arc<EmuTuning>>,
    payload_scale: f64,
    steps_total: usize,
    compute_inflation: f64,
    coord_latency: f64,
    bucket_count: Arc<AtomicU64>,
) -> Result<WorkerOutcome> {
    let me = ep.me();
    let mut rng = Rng::new(exp.seed ^ (me.0 as u64) << 32);
    let compression_ratio = exp.compression.ratio();

    // The async collective engine replaces the ad-hoc comm thread: FIFO
    // background execution of the configured collective, with the
    // per-bucket negotiation latency charged on the worker thread.
    let engine = AsyncCollectiveEngine::new(Arc::clone(&ep), exp.collective);

    // Worker 0 owns the controller when autotuning; everyone else only
    // reads the shared knob cell.
    let mut tuner: Option<AutoTuner> = match &tuning {
        Some(_) if me.0 == 0 => Some(AutoTuner::new(
            emu_knob_space(exp),
            emu_tuner_config(exp),
            &emu_initial_point(exp),
        )?),
        _ => None,
    };

    let mut phase = PhaseTimes::default();
    let mut measured_wall = 0.0f64;
    let mut handles: Vec<AllReduceHandle> = Vec::with_capacity(timeline.len());
    let mut deferred: Vec<(u32, Vec<f32>)> = Vec::new();
    for step in 0..steps_total {
        let measured = step >= exp.warmup_steps;
        let step_start = Instant::now();
        let _total_sp = crate::span!("step.total", me.0, step);
        {
            let _sp = crate::span!("step.barrier", me.0, step);
            barrier(ep.as_ref(), step as u32)?;
        }

        // Knobs for this step: the barrier above orders worker 0's
        // end-of-previous-step write before this read on every rank, so
        // all workers bucket identically.
        let (step_timeline, step_ratio) = match &tuning {
            Some(t) => {
                let k = *t.current.lock().unwrap();
                let tl = if k.bucket_mb > 0.0 {
                    t.cache.get(crate::sched::bucket::mb_to_threshold(k.bucket_mb))
                } else {
                    Arc::clone(&timeline)
                };
                (tl, k.compression.ratio())
            }
            None => (Arc::clone(&timeline), compression_ratio),
        };

        // ---- Forward (modeled). ----
        let compute_sp = crate::span!("step.compute", me.0, step);
        let t_fwd = trace.t_forward * compute_inflation;
        spin_sleep(t_fwd);

        // ---- Backward (modeled): replay the deterministic bucket
        // timeline, sleeping to each emission instant. Under `--overlap
        // buckets` each bucket enters the engine the moment it is
        // emitted; under `--overlap off` the identical buckets are held
        // back until backward finishes (the serialized baseline). ----
        let backward_start = Instant::now();
        for (seq, (t_emit, bytes)) in step_timeline.iter().enumerate() {
            let target = t_emit * compute_inflation;
            let elapsed = backward_start.elapsed().as_secs_f64();
            if target > elapsed {
                spin_sleep(target - elapsed);
            }
            // Wire size: scaled + compressed. A tiny floor keeps zero-byte
            // buckets representable.
            let wire_elems = ((*bytes as f64 / payload_scale / step_ratio / 4.0)
                as usize)
                .max(1);
            let mut data = vec![0.0f32; wire_elems];
            rng.fill_f32(&mut data, 1.0);
            bucket_count.fetch_add(1, Ordering::Relaxed);
            match exp.overlap {
                OverlapMode::Buckets => handles.push(engine.submit_after(
                    step as u32,
                    seq as u32,
                    data,
                    coord_latency,
                )),
                OverlapMode::Off => deferred.push((seq as u32, data)),
            }
        }
        // Finish out the backward pass (tail after the last emission).
        {
            let target = trace.t_backward * compute_inflation;
            let elapsed = backward_start.elapsed().as_secs_f64();
            if target > elapsed {
                spin_sleep(target - elapsed);
            }
        }
        drop(compute_sp);
        let compute_s = step_start.elapsed().as_secs_f64();

        // Blocking mode: the buckets only reach the wire now.
        let wait_sp = crate::span!("step.wait", me.0, step);
        for (seq, data) in deferred.drain(..) {
            handles.push(engine.submit_after(step as u32, seq, data, coord_latency));
        }

        // ---- Wait for the all-reduce process to drain (t_sync). ----
        let wait_start = Instant::now();
        for h in handles.drain(..) {
            std::hint::black_box(h.wait()?);
        }
        drop(wait_sp);
        let comm_wait = wait_start.elapsed().as_secs_f64();

        if measured {
            phase.add_compute(compute_s);
            phase.add_comm(comm_wait);
            phase.end_step();
            measured_wall += step_start.elapsed().as_secs_f64();
        }

        // Close the loop: worker 0 feeds the controller and publishes any
        // knob change for every rank to pick up after the next barrier.
        if let (Some(shared), Some(tu)) = (&tuning, tuner.as_mut()) {
            let fb = StepFeedback {
                step: step as u64,
                wall_s: step_start.elapsed().as_secs_f64(),
                compute_s,
                comm_busy_s: comm_wait,
                busbw_gbps: 0.0,
            };
            if let Some(next) = tu.observe(&fb) {
                *shared.current.lock().unwrap() = next;
            }
        }
    }
    Ok(WorkerOutcome {
        phase,
        measured_wall_s: measured_wall,
        tuning: tuner.map(|t| {
            let mut s = t.summary();
            // A decision made at the final step never took effect (no
            // next step read it): count only points that genuinely ran.
            s.trajectory.retain(|(step, _)| *step < steps_total as u64);
            s.changes = s.trajectory.len().saturating_sub(1);
            s
        }),
    })
}

/// Sleep that tolerates the coarse scheduler on a busy 1-core box: OS
/// sleep for the bulk, spin for the last stretch only when short.
fn spin_sleep(seconds: f64) {
    if seconds <= 0.0 {
        return;
    }
    let start = Instant::now();
    if seconds > 0.0005 {
        std::thread::sleep(Duration::from_secs_f64(seconds - 0.0003));
    }
    while start.elapsed().as_secs_f64() < seconds {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Compression, ExperimentConfig};
    use crate::models::ModelId;

    fn quick_cfg(servers: usize, bw: f64, transport: TransportKind) -> EmulatedRunConfig {
        let exp = ExperimentConfig {
            model: ModelId::ResNet50,
            servers,
            gpus_per_server: 1,
            bandwidth_gbps: bw,
            transport,
            steps: 3,
            warmup_steps: 1,
            ..Default::default()
        };
        // Aggressive payload scale keeps tests fast.
        EmulatedRunConfig { exp, payload_scale: 2048.0 }
    }

    #[test]
    fn emulated_run_completes_and_reports() {
        let r = run_emulated(&quick_cfg(2, 100.0, TransportKind::FullUtilization)).unwrap();
        assert_eq!(r.workers, 2);
        assert_eq!(r.steps, 3);
        assert!(r.step_time_s > 0.0);
        assert!(r.throughput > 0.0);
        assert!(r.scaling_factor > 0.2 && r.scaling_factor <= 1.05, "{}", r.scaling_factor);
        assert!(r.buckets_per_step >= 1.0);
    }

    #[test]
    fn full_utilization_beats_kernel_tcp_at_high_bw() {
        let ideal = run_emulated(&quick_cfg(2, 100.0, TransportKind::FullUtilization)).unwrap();
        let horovod = run_emulated(&quick_cfg(2, 100.0, TransportKind::KernelTcp)).unwrap();
        assert!(
            ideal.scaling_factor > horovod.scaling_factor,
            "{} vs {}",
            ideal.scaling_factor,
            horovod.scaling_factor
        );
    }

    #[test]
    fn compression_improves_low_bandwidth() {
        let mut plain = quick_cfg(2, 1.0, TransportKind::FullUtilization);
        plain.exp.model = ModelId::Vgg16;
        let mut compressed = plain.clone();
        compressed.exp.compression = Compression::Ratio(10.0);
        let a = run_emulated(&plain).unwrap();
        let b = run_emulated(&compressed).unwrap();
        assert!(b.scaling_factor > a.scaling_factor, "{} vs {}", b.scaling_factor, a.scaling_factor);
    }

    #[test]
    fn single_worker_near_perfect() {
        let r = run_emulated(&quick_cfg(1, 100.0, TransportKind::FullUtilization)).unwrap();
        assert!(r.scaling_factor > 0.9, "{}", r.scaling_factor);
    }

    #[test]
    fn striped_emulation_completes_and_reports() {
        // The mechanistic striped path: NIC at the provisioned rate,
        // per-stream gates, real chunked frames through the collectives.
        let r = run_emulated(&quick_cfg(2, 100.0, TransportKind::Striped { streams: 4 })).unwrap();
        assert_eq!(r.workers, 2);
        assert!(r.step_time_s > 0.0);
        assert!(r.scaling_factor > 0.2 && r.scaling_factor <= 1.05, "{}", r.scaling_factor);
        assert!(r.buckets_per_step >= 1.0);
    }

    #[test]
    fn hierarchical_emulation_completes_and_reports() {
        // The leader-ring collective over the emulated fabric: 4 workers
        // in groups of 2 (`--collective hier:2`).
        let mut cfg = quick_cfg(4, 25.0, TransportKind::FullUtilization);
        cfg.exp.collective = crate::config::CollectiveKind::Hierarchical { group_size: 2 };
        let r = run_emulated(&cfg).unwrap();
        assert_eq!(r.workers, 4);
        assert!(r.step_time_s > 0.0);
        assert!(r.scaling_factor > 0.1 && r.scaling_factor <= 1.05, "{}", r.scaling_factor);
    }

    #[test]
    fn blocking_overlap_never_beats_bucketized() {
        // Same experiment, only the submission policy differs: blocking
        // serializes comm after backward, so its step time must not be
        // (meaningfully) shorter. A compute-heavy model at a modest rate
        // keeps the gap visible over scheduler noise.
        let mut on = quick_cfg(2, 5.0, TransportKind::FullUtilization);
        on.exp.model = ModelId::Vgg16;
        on.exp.overlap = crate::config::OverlapMode::Buckets;
        let mut off = on.clone();
        off.exp.overlap = crate::config::OverlapMode::Off;
        let a = run_emulated(&on).unwrap();
        let b = run_emulated(&off).unwrap();
        assert!(
            b.step_time_s > a.step_time_s * 0.9,
            "blocking {} vs overlapped {}",
            b.step_time_s,
            a.step_time_s
        );
        assert!(b.mean_comm_wait_s >= a.mean_comm_wait_s * 0.5);
    }

    #[test]
    fn bucket_mb_switches_the_bucket_source() {
        // A 4 MB threshold on ResNet50 produces many more buckets than
        // the 64 MB fusion buffer.
        let fused = quick_cfg(2, 100.0, TransportKind::FullUtilization);
        let mut ddp = fused.clone();
        ddp.exp.bucket_mb = 4.0;
        let a = run_emulated(&fused).unwrap();
        let b = run_emulated(&ddp).unwrap();
        assert!(
            b.buckets_per_step > a.buckets_per_step,
            "ddp {} vs fusion {}",
            b.buckets_per_step,
            a.buckets_per_step
        );
    }

    #[test]
    fn autotuned_emulation_reports_a_trajectory() {
        // The control loop end to end on the emulated bed: worker 0 runs
        // the controller, every rank follows the shared knob cell, the
        // run completes and reports the trajectory.
        let mut cfg = quick_cfg(2, 25.0, TransportKind::FullUtilization);
        cfg.exp.autotune.enabled = true;
        cfg.exp.autotune.bucket_mbs = vec![4.0, 32.0];
        cfg.exp.autotune.compressions =
            vec![crate::config::Compression::None, crate::config::Compression::Ratio(4.0)];
        cfg.exp.steps = 10;
        cfg.exp.warmup_steps = 1;
        let r = run_emulated(&cfg).unwrap();
        assert_eq!(r.workers, 2);
        assert!(r.step_time_s > 0.0);
        let summary = r.autotune.expect("autotuned run must carry a summary");
        assert!(!summary.trajectory.is_empty());
        assert_eq!(summary.trajectory[0].0, 0, "entry 0 is the initial point");
        assert_eq!(summary.changes, summary.trajectory.len() - 1);
        assert!(summary.probe_phases >= 1);
        // The probing actually happened: with an 11-step run and a
        // 2+2-step probe cadence, at least one candidate was applied.
        assert!(summary.changes >= 1, "{summary:?}");
    }

    #[test]
    fn autotune_space_preserves_the_configured_operating_point() {
        // The user's own compression/bucket settings must be exactly
        // representable in the tuner's grid — autotune may move away from
        // them, never silently replace them with a default candidate.
        let mut exp = ExperimentConfig::default();
        exp.autotune.enabled = true;
        exp.compression = Compression::Ratio(50.0);
        exp.bucket_mb = 7.0;
        let space = emu_knob_space(&exp);
        space.validate().unwrap();
        assert!(space.compressions.contains(&Compression::Ratio(50.0)));
        assert!(space.bucket_mbs.contains(&7.0));
        let start = space.point_at(space.nearest_index(&emu_initial_point(&exp)));
        assert_eq!(start.compression.ratio(), 50.0);
        assert_eq!(start.bucket_mb, 7.0);
    }

    #[test]
    fn static_runs_carry_no_tuning_summary() {
        let r = run_emulated(&quick_cfg(2, 100.0, TransportKind::FullUtilization)).unwrap();
        assert!(r.autotune.is_none());
    }

    #[test]
    fn bucket_timeline_conserves_bytes_and_is_sorted() {
        use crate::models::timing::backward_trace;
        for id in [ModelId::ResNet50, ModelId::Vgg16] {
            let trace = backward_trace(&id.profile());
            let tl = bucket_timeline(&trace, crate::config::FusionConfig::default());
            let total: usize = tl.iter().map(|(_, b)| *b).sum();
            assert_eq!(total, id.profile().total_bytes(), "{id}");
            for w in tl.windows(2) {
                assert!(w[0].0 <= w[1].0, "{id}: timeline not sorted");
            }
            assert!(tl.last().unwrap().0 <= trace.t_backward + 1e-12);
        }
    }

    #[test]
    fn bucket_timeline_identical_across_calls() {
        use crate::models::timing::backward_trace;
        let trace = backward_trace(&ModelId::ResNet101.profile());
        let a = bucket_timeline(&trace, crate::config::FusionConfig::default());
        let b = bucket_timeline(&trace, crate::config::FusionConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn spin_sleep_accuracy() {
        let t0 = Instant::now();
        spin_sleep(0.01);
        let dt = t0.elapsed().as_secs_f64();
        assert!((0.009..0.05).contains(&dt), "{dt}");
    }
}
