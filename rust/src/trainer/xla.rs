//! Real-compute trainer: drives the AOT transformer train-step artifacts
//! through the PJRT device service, with distributed gradient averaging
//! over the fabric — the e2e path proving all three layers compose.
//!
//! Artifact contract (produced by `python/compile/aot.py`):
//!
//! * `train_fwd_bwd.hlo.txt` — `(params f32[P], tokens i32[B,S+1]) ->
//!   (loss f32[], grads f32[P])`
//! * `apply_sgd.hlo.txt` — `(params f32[P], grads f32[P], lr f32[]) ->
//!   (params f32[P],)`
//! * `model_meta.txt` — `param_count/vocab/seq/batch` plus one
//!   `layer <name> <offset> <elems>` line per parameter tensor
//! * `init_params.bin` — P little-endian f32
//!
//! Note on overlap: XLA returns all gradients at once (no per-layer hooks
//! mid-executable), so the e2e path cannot overlap backward with
//! all-reduce the way the paper's Horovod setup does — overlap is the
//! modeled emulator's job ([`super::run_emulated`]). Here the gradients
//! still flow through the fusion buffer so the wire sees the same
//! bucketing, and numerics are exact.

use crate::collectives::fusion::{FusionBuffer, GradTensor};
use crate::collectives::reduce::scale;
use crate::collectives::ring::ring_allreduce;
use crate::net::{Endpoint, Fabric};
use crate::runtime::{DeviceHandle, HostTensor};
use crate::topology::{Ring, Topology};
use crate::util::Rng;
use crate::Result;
use anyhow::Context;
use std::path::Path;
use std::sync::Arc;

/// One parameter tensor's slice of the flat parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpan {
    pub name: String,
    pub offset: usize,
    pub elems: usize,
}

/// Parsed `model_meta.txt`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub param_count: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// Spans in *backward completion order* is not knowable from XLA; we
    /// keep forward order and emit reversed (output-side layers first),
    /// matching how gradients become available in backprop.
    pub layers: Vec<LayerSpan>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("model_meta.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?}; run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ModelMeta> {
        let (mut param_count, mut vocab, mut seq, mut batch) = (0usize, 0usize, 0usize, 0usize);
        let mut layers = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap();
            let vals: Vec<&str> = parts.collect();
            let bad = || anyhow::anyhow!("model_meta line {}: {line:?}", lineno + 1);
            match key {
                "param_count" => param_count = vals.first().ok_or_else(bad)?.parse()?,
                "vocab" => vocab = vals.first().ok_or_else(bad)?.parse()?,
                "seq" => seq = vals.first().ok_or_else(bad)?.parse()?,
                "batch" => batch = vals.first().ok_or_else(bad)?.parse()?,
                "layer" => {
                    anyhow::ensure!(vals.len() == 3, bad());
                    layers.push(LayerSpan {
                        name: vals[0].to_string(),
                        offset: vals[1].parse()?,
                        elems: vals[2].parse()?,
                    });
                }
                _ => anyhow::bail!("unknown model_meta key {key:?}"),
            }
        }
        anyhow::ensure!(param_count > 0, "param_count missing");
        anyhow::ensure!(!layers.is_empty(), "no layer spans");
        let covered: usize = layers.iter().map(|l| l.elems).sum();
        anyhow::ensure!(
            covered == param_count,
            "layer spans cover {covered} of {param_count} params"
        );
        Ok(ModelMeta { param_count, vocab, seq, batch, layers })
    }
}

/// Load `init_params.bin`.
pub fn load_init_params(dir: &Path, expected: usize) -> Result<Vec<f32>> {
    let path = dir.join("init_params.bin");
    let bytes = std::fs::read(&path)
        .with_context(|| format!("read {path:?}; run `make artifacts`"))?;
    anyhow::ensure!(
        bytes.len() == expected * 4,
        "init_params.bin holds {} bytes, expected {}",
        bytes.len(),
        expected * 4
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Synthetic token stream with learnable next-token structure: an affine
/// map over the vocab plus noise. Loss should fall well below ln(vocab).
pub struct DataGen {
    rng: Rng,
    vocab: usize,
    noise: f64,
}

impl DataGen {
    pub fn new(seed: u64, vocab: usize, noise: f64) -> DataGen {
        DataGen { rng: Rng::new(seed), vocab, noise }
    }

    /// Generate `[batch, seq+1]` tokens (inputs ‖ shifted targets).
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut tok = self.rng.next_below(self.vocab as u64) as i64;
            for _ in 0..=seq {
                out.push(tok as i32);
                tok = if self.rng.bool_with_p(self.noise) {
                    self.rng.next_below(self.vocab as u64) as i64
                } else {
                    (tok * 3 + 7) % self.vocab as i64
                };
            }
        }
        out
    }
}

/// The real-compute trainer.
pub struct XlaTrainer {
    pub handle: DeviceHandle,
    pub meta: ModelMeta,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainResult {
    /// Mean loss per step (averaged across workers).
    pub loss_curve: Vec<f64>,
    /// Wall time per step.
    pub step_times: Vec<f64>,
    pub workers: usize,
    /// Final parameters of worker 0 (for cross-run equality checks).
    pub final_params: Vec<f32>,
}

impl XlaTrainer {
    pub fn new(handle: DeviceHandle, meta: ModelMeta) -> XlaTrainer {
        XlaTrainer { handle, meta }
    }

    /// One gradient computation: `(loss, grads)`.
    pub fn grad_step(&self, params: &[f32], tokens: &[i32]) -> Result<(f64, Vec<f32>)> {
        let b = (tokens.len() / (self.meta.seq + 1)) as i64;
        let out = self.handle.exec(
            "train_fwd_bwd",
            vec![
                HostTensor::f32(&[self.meta.param_count as i64], params.to_vec()),
                HostTensor::i32(&[b, (self.meta.seq + 1) as i64], tokens.to_vec()),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "train_fwd_bwd returned {} outputs", out.len());
        let loss = out[0].mean_f32()?;
        let grads = out[1].clone().into_f32()?;
        Ok((loss, grads))
    }

    /// SGD application through the AOT artifact.
    pub fn apply(&self, params: &[f32], grads: &[f32], lr: f32) -> Result<Vec<f32>> {
        let p = self.meta.param_count as i64;
        let out = self.handle.exec(
            "apply_sgd",
            vec![
                HostTensor::f32(&[p], params.to_vec()),
                HostTensor::f32(&[p], grads.to_vec()),
                HostTensor::scalar_f32(lr),
            ],
        )?;
        anyhow::ensure!(out.len() == 1, "apply_sgd returned {} outputs", out.len());
        out[0].clone().into_f32()
    }

    /// Single-device training baseline.
    pub fn train_single(
        &self,
        init: Vec<f32>,
        steps: usize,
        batch: usize,
        lr: f32,
        seed: u64,
    ) -> Result<TrainResult> {
        let mut params = init;
        let mut gen = DataGen::new(seed, self.meta.vocab, 0.1);
        let mut loss_curve = Vec::with_capacity(steps);
        let mut step_times = Vec::with_capacity(steps);
        for _ in 0..steps {
            let t0 = std::time::Instant::now();
            let tokens = gen.batch(batch, self.meta.seq);
            let (loss, grads) = self.grad_step(&params, &tokens)?;
            params = self.apply(&params, &grads, lr)?;
            loss_curve.push(loss);
            step_times.push(t0.elapsed().as_secs_f64());
        }
        Ok(TrainResult { loss_curve, step_times, workers: 1, final_params: params })
    }

    /// Distributed data-parallel training over `fabric` (one thread per
    /// worker; compute serializes through the device service, gradients
    /// average over real ring all-reduce with fusion bucketing).
    pub fn train_distributed(
        &self,
        fabric: &dyn Fabric,
        init: Vec<f32>,
        steps: usize,
        batch_per_worker: usize,
        lr: f32,
        seed: u64,
        fusion: crate::config::FusionConfig,
    ) -> Result<TrainResult> {
        let endpoints = fabric.endpoints();
        let workers = endpoints.len();
        let topo = Topology::new(workers, 1);
        let ring = topo.flat_ring();
        let mut handles = Vec::new();
        for ep in endpoints {
            let meta = self.meta.clone();
            let handle = self.handle.clone();
            let init = init.clone();
            let ring = ring.clone();
            handles.push(std::thread::spawn(move || {
                distributed_worker(
                    XlaTrainer { handle, meta },
                    ep,
                    ring,
                    init,
                    steps,
                    batch_per_worker,
                    lr,
                    seed,
                    fusion,
                )
            }));
        }
        let mut outcomes = Vec::new();
        for h in handles {
            outcomes.push(h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??);
        }
        // Mean loss across workers per step; max step time.
        let mut loss_curve = vec![0.0f64; steps];
        let mut step_times = vec![0.0f64; steps];
        for o in &outcomes {
            for (i, l) in o.loss_curve.iter().enumerate() {
                loss_curve[i] += l / workers as f64;
            }
            for (i, t) in o.step_times.iter().enumerate() {
                step_times[i] = step_times[i].max(*t);
            }
        }
        Ok(TrainResult {
            loss_curve,
            step_times,
            workers,
            final_params: outcomes.into_iter().next().unwrap().final_params,
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn distributed_worker(
    trainer: XlaTrainer,
    ep: Arc<dyn Endpoint>,
    ring: Ring,
    init: Vec<f32>,
    steps: usize,
    batch: usize,
    lr: f32,
    seed: u64,
    fusion_cfg: crate::config::FusionConfig,
) -> Result<TrainResult> {
    let me = ep.me();
    let mut params = init;
    // Different data stream per worker — the whole point of data parallel.
    let mut gen = DataGen::new(seed ^ ((me.0 as u64 + 1) << 40), trainer.meta.vocab, 0.1);
    let world = ring.len() as f32;
    let mut loss_curve = Vec::with_capacity(steps);
    let mut step_times = Vec::with_capacity(steps);
    for step in 0..steps {
        let t0 = std::time::Instant::now();
        let tokens = gen.batch(batch, trainer.meta.seq);
        let (loss, mut grads) = trainer.grad_step(&params, &tokens)?;

        // Fusion bucketing over the layer table (reverse order: gradients
        // conceptually complete output-side first).
        let mut fusion = FusionBuffer::new(fusion_cfg);
        let mut buckets = Vec::new();
        for (i, span) in trainer.meta.layers.iter().enumerate().rev() {
            let t = GradTensor::with_data(
                span.offset, // layer id = offset (unique, recoverable)
                grads[span.offset..span.offset + span.elems].to_vec(),
            );
            let now = i as f64 * 1e-4; // virtual emission clock
            buckets.extend(fusion.push(t, now));
        }
        buckets.extend(fusion.flush());

        // All-reduce each bucket; scatter results back into the flat grad.
        for (seq, bucket) in buckets.into_iter().enumerate() {
            let mut flat: Vec<f32> = Vec::with_capacity(bucket.bytes / 4);
            let spans: Vec<(usize, usize)> = bucket
                .tensors
                .iter()
                .map(|t| {
                    let data = t.data.as_ref().expect("e2e buckets carry data");
                    flat.extend_from_slice(data);
                    (t.layer, data.len())
                })
                .collect();
            ring_allreduce(ep.as_ref(), &ring, step as u32, seq as u32, &mut flat)?;
            scale(&mut flat, 1.0 / world);
            let mut cursor = 0;
            for (offset, len) in spans {
                grads[offset..offset + len].copy_from_slice(&flat[cursor..cursor + len]);
                cursor += len;
            }
        }

        params = trainer.apply(&params, &grads, lr)?;
        loss_curve.push(loss);
        step_times.push(t0.elapsed().as_secs_f64());
    }
    Ok(TrainResult { loss_curve, step_times, workers: ring.len(), final_params: params })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_and_validates() {
        let m = ModelMeta::parse(
            "param_count 10\nvocab 512\nseq 64\nbatch 8\nlayer a 0 4\nlayer b 4 6\n",
        )
        .unwrap();
        assert_eq!(m.param_count, 10);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[1], LayerSpan { name: "b".into(), offset: 4, elems: 6 });
    }

    #[test]
    fn meta_rejects_bad_coverage() {
        let err = ModelMeta::parse("param_count 10\nlayer a 0 4\n").unwrap_err().to_string();
        assert!(err.contains("cover 4 of 10"), "{err}");
    }

    #[test]
    fn meta_rejects_unknown_key() {
        assert!(ModelMeta::parse("bogus 1\n").is_err());
    }

    #[test]
    fn datagen_shape_and_range() {
        let mut g = DataGen::new(1, 100, 0.1);
        let b = g.batch(3, 16);
        assert_eq!(b.len(), 3 * 17);
        assert!(b.iter().all(|t| (0..100).contains(t)));
    }

    #[test]
    fn datagen_is_predictable_structure() {
        // With zero noise the next token is a deterministic function.
        let mut g = DataGen::new(2, 97, 0.0);
        let b = g.batch(1, 10);
        for w in b.windows(2) {
            assert_eq!(w[1] as i64, (w[0] as i64 * 3 + 7) % 97);
        }
    }
}
