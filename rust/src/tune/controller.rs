//! The **AutoTuner** — a seeded warmup → probe → exploit state machine
//! that closes the measure→adapt loop.
//!
//! The tuner owns a [`KnobSpace`] coordinate and improves it by
//! **coordinate descent**: each probe phase sweeps every value on one
//! axis (holding the others fixed), measures each candidate for
//! `probe_steps` steps, and moves to the best value only when it beats
//! the incumbent by more than the `hysteresis` margin — small wins are
//! noise, and flapping between near-equal knobs costs reconfigurations.
//! After `max_passes` over the (seeded, shuffled) axis order — or a full
//! pass with no movement — the tuner **exploits**: it pins the chosen
//! point and watches a rolling window of step walls. A window slower
//! than the exploit baseline by more than `regress_threshold`, sustained
//! for `regress_patience` consecutive windows, means the environment
//! moved (a NIC rate change, a neighbor stealing bandwidth): the tuner
//! re-enters probe and finds the new operating point.
//!
//! Determinism: decisions are a pure function of the seed and the
//! feedback values. Identical seeds and identical feedback sequences
//! yield identical knob trajectories — the property the tuner-determinism
//! suite (and serial ≡ `--parallel` sweep equality) pins down.
//!
//! The driver contract is [`AutoTuner::observe`]: call it once per
//! completed step with that step's [`StepFeedback`] (measured under
//! [`AutoTuner::current`]); when it returns `Some(point)`, reconfigure to
//! `point` before the next step begins. Harnesses that can only
//! reconfigure a subset of the axes online (the launch path tunes
//! `chunk_kb`; the emulated trainer tunes `bucket_mb` × `compression`)
//! freeze the other axes by building a space with single-valued axes.

use super::feedback::{FeedbackRing, StepFeedback};
use super::knobs::{KnobIndex, KnobPoint, KnobSpace, AXES};
use crate::report::json_str;
use crate::util::{json, Rng};
use crate::Result;
use anyhow::{ensure, Context};

/// Controller parameters.
#[derive(Clone, Copy, Debug)]
pub struct TunerConfig {
    /// Steps discarded before the first probe (connection caches, JIT).
    /// At least one step is always discarded — the first observation
    /// arrives only after a step has already run.
    pub warmup_steps: usize,
    /// Steps measured per candidate, and the exploit window length.
    pub probe_steps: usize,
    /// Minimum relative improvement required to move along an axis.
    pub hysteresis: f64,
    /// Relative slowdown vs the exploit baseline that counts as a
    /// regression.
    pub regress_threshold: f64,
    /// Consecutive regressed windows before a re-probe.
    pub regress_patience: usize,
    /// Maximum coordinate-descent passes per probe phase.
    pub max_passes: usize,
    pub seed: u64,
}

impl Default for TunerConfig {
    fn default() -> TunerConfig {
        TunerConfig {
            warmup_steps: 2,
            probe_steps: 2,
            hysteresis: 0.03,
            regress_threshold: 0.25,
            regress_patience: 3,
            max_passes: 3,
            seed: 0x7a0e,
        }
    }
}

impl TunerConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.probe_steps >= 1, "tuner probe_steps must be >= 1");
        ensure!(self.max_passes >= 1, "tuner max_passes must be >= 1");
        ensure!(self.regress_patience >= 1, "tuner regress_patience must be >= 1");
        ensure!(
            self.hysteresis.is_finite() && (0.0..1.0).contains(&self.hysteresis),
            "tuner hysteresis must be in [0, 1)"
        );
        ensure!(
            self.regress_threshold.is_finite() && self.regress_threshold > 0.0,
            "tuner regress_threshold must be > 0"
        );
        Ok(())
    }
}

/// Which phase the controller is in (surfaced for reporting/tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunerState {
    Warmup,
    Probe,
    Exploit,
}

/// What a finished tuning run decided — the report both trainer paths
/// attach to their results.
#[derive(Clone, Debug)]
pub struct TuningSummary {
    /// Applied knob changes (trajectory entries beyond the initial point).
    pub changes: usize,
    /// The chosen operating point.
    pub final_knobs: KnobPoint,
    /// Probe phases entered (≥ 2 means at least one re-probe fired).
    pub probe_phases: usize,
    /// `(first step the point was active, point)`, initial point first.
    pub trajectory: Vec<(u64, KnobPoint)>,
}

/// A tuner's learned state, reduced to what is worth carrying across
/// process restarts: the chosen operating point and the evidence behind
/// it. `netbn serve` persists one per scenario under `<store>/tuner/`
/// and warm-starts resubmitted jobs from it — the first slice of the
/// ROADMAP's "persist tuner state" item. The wire format is JSON built
/// on [`KnobPoint::spec`]/[`KnobPoint::parse_spec`], so checkpoints stay
/// readable and diffable.
#[derive(Clone, Debug, PartialEq)]
pub struct TunerCheckpoint {
    /// The chosen operating point at save time.
    pub chosen: KnobPoint,
    /// Exploit baseline (mean step wall of the chosen point), seconds;
    /// NaN when the tuner never finished a probe.
    pub baseline_s: f64,
    /// Steps observed when the checkpoint was taken.
    pub steps_seen: u64,
    /// Probe phases entered when the checkpoint was taken.
    pub probe_phases: usize,
}

impl TunerCheckpoint {
    /// A checkpoint holding only a chosen point (e.g. recovered from a
    /// finished run's report rather than a live tuner).
    pub fn from_point(chosen: KnobPoint) -> TunerCheckpoint {
        TunerCheckpoint { chosen, baseline_s: f64::NAN, steps_seen: 0, probe_phases: 0 }
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\"chosen\":{},\"baseline_s\":{},\"steps_seen\":{},\"probe_phases\":{}}}",
            json_str(&self.chosen.spec()),
            if self.baseline_s.is_finite() { format!("{}", self.baseline_s) } else { "null".to_string() },
            self.steps_seen,
            self.probe_phases
        )
    }

    pub fn from_json(s: &str) -> Result<TunerCheckpoint> {
        let fields = json::object_fields(s).context("malformed tuner checkpoint")?;
        let chosen = KnobPoint::parse_spec(&json::parse_string(json::require(&fields, "chosen")?)?)?;
        Ok(TunerCheckpoint {
            chosen,
            baseline_s: json::parse_f64(json::require(&fields, "baseline_s")?)?,
            steps_seen: json::parse_u64(json::require(&fields, "steps_seen")?)?,
            probe_phases: json::parse_u64(json::require(&fields, "probe_phases")?)? as usize,
        })
    }
}

/// Probe-phase bookkeeping: one axis sweep at a time.
#[derive(Clone, Debug)]
struct ProbeState {
    /// Seeded shuffle of the axis indices for this phase.
    axis_order: Vec<usize>,
    /// Position in `axis_order`.
    axis_pos: usize,
    /// Completed passes over the whole order.
    pass: usize,
    /// Did any axis move during the current pass?
    moved_this_pass: bool,
    /// Candidate value indices on the current axis.
    candidates: Vec<usize>,
    cand_pos: usize,
    /// Wall samples for the current candidate.
    samples: Vec<f64>,
    /// `(value index, mean wall)` for finished candidates on this axis.
    cand_means: Vec<(usize, f64)>,
}

/// The online autotuner (see module docs).
pub struct AutoTuner {
    space: KnobSpace,
    cfg: TunerConfig,
    /// The coordinate the harness currently runs.
    applied: KnobIndex,
    /// The best-known coordinate (what exploit pins).
    chosen: KnobIndex,
    state: TunerState,
    warmup_left: usize,
    probe: Option<ProbeState>,
    /// Exploit baseline: mean wall of the chosen point when it was last
    /// probed.
    baseline: f64,
    /// Every observation lands here; the exploit-phase regression watch
    /// reads its rolling window back out (`window_fill` counts samples
    /// since the last window boundary).
    ring: FeedbackRing,
    window_fill: usize,
    slow_windows: usize,
    rng: Rng,
    steps_seen: u64,
    /// Applied knob changes: `(step index at which the change took
    /// effect, point)`. Entry 0 is the initial point.
    trajectory: Vec<(u64, KnobPoint)>,
    /// Probe phases entered (1 after the initial probe; +1 per re-probe).
    probe_phases: usize,
}

impl AutoTuner {
    /// Create a tuner over `space`, starting at the grid point nearest to
    /// `initial` (a harness's static config).
    pub fn new(space: KnobSpace, cfg: TunerConfig, initial: &KnobPoint) -> Result<AutoTuner> {
        space.validate()?;
        cfg.validate()?;
        let start = space.nearest_index(initial);
        let start_point = space.point_at(start);
        Ok(AutoTuner {
            space,
            cfg,
            applied: start,
            chosen: start,
            state: TunerState::Warmup,
            warmup_left: cfg.warmup_steps.max(1),
            probe: None,
            baseline: f64::INFINITY,
            ring: FeedbackRing::new(cfg.probe_steps.max(8) * 8),
            window_fill: 0,
            slow_windows: 0,
            rng: Rng::new(cfg.seed),
            steps_seen: 0,
            trajectory: vec![(0, start_point)],
            probe_phases: 0,
        })
    }

    /// The point the harness should be running right now.
    pub fn current(&self) -> KnobPoint {
        self.space.point_at(self.applied)
    }

    /// The best point found so far (what exploit runs).
    pub fn chosen(&self) -> KnobPoint {
        self.space.point_at(self.chosen)
    }

    pub fn state(&self) -> TunerState {
        self.state
    }

    /// Knob decisions, `(first step the point takes effect, point)`. A
    /// decision made while observing the run's final step never actually
    /// runs — harness reports filter entries whose step is past the run
    /// horizon (which the controller cannot know).
    pub fn trajectory(&self) -> &[(u64, KnobPoint)] {
        &self.trajectory
    }

    /// Steps observed so far.
    pub fn steps_seen(&self) -> u64 {
        self.steps_seen
    }

    /// Probe phases entered so far (≥ 2 once a re-probe has happened).
    pub fn probe_phases(&self) -> usize {
        self.probe_phases
    }

    /// Exploit-phase baseline (mean step wall of the chosen point).
    pub fn baseline_s(&self) -> f64 {
        self.baseline
    }

    /// The bounded window of recent observations (every feedback sample
    /// ever passed to [`AutoTuner::observe`] lands here; reporters read
    /// recent means/dispersion from it).
    pub fn feedback(&self) -> &FeedbackRing {
        &self.ring
    }

    /// Summarize the run so far.
    pub fn summary(&self) -> TuningSummary {
        TuningSummary {
            changes: self.trajectory.len().saturating_sub(1),
            final_knobs: self.chosen(),
            probe_phases: self.probe_phases,
            trajectory: self.trajectory.clone(),
        }
    }

    /// Snapshot the learned state for persistence (see
    /// [`TunerCheckpoint`]).
    pub fn checkpoint(&self) -> TunerCheckpoint {
        TunerCheckpoint {
            chosen: self.chosen(),
            baseline_s: if self.baseline.is_finite() { self.baseline } else { f64::NAN },
            steps_seen: self.steps_seen,
            probe_phases: self.probe_phases,
        }
    }

    /// A tuner warm-started from a persisted checkpoint: the coordinate
    /// descent begins at the previously chosen point (snapped to the
    /// nearest grid point of `space`) instead of the harness default, so
    /// a resubmitted job re-probes *around* the known-good operating
    /// point rather than from scratch.
    pub fn from_checkpoint(
        space: KnobSpace,
        cfg: TunerConfig,
        ck: &TunerCheckpoint,
    ) -> Result<AutoTuner> {
        AutoTuner::new(space, cfg, &ck.chosen)
    }

    /// Feed one completed step's feedback (measured under
    /// [`AutoTuner::current`]); returns the point to apply before the
    /// next step when a change is wanted.
    pub fn observe(&mut self, fb: &StepFeedback) -> Option<KnobPoint> {
        self.steps_seen += 1;
        self.ring.push(*fb);
        match self.state {
            TunerState::Warmup => {
                if self.warmup_left > 1 {
                    self.warmup_left -= 1;
                    return None;
                }
                self.enter_probe()
            }
            TunerState::Probe => self.observe_probe(fb.wall_s),
            TunerState::Exploit => self.observe_exploit(fb.wall_s),
        }
    }

    /// Start a (re-)probe phase: fresh seeded axis order, first axis
    /// sweep armed. Returns the first candidate to apply.
    fn enter_probe(&mut self) -> Option<KnobPoint> {
        self.state = TunerState::Probe;
        self.probe_phases += 1;
        // Axes with one value can never move; dropping them up front keeps
        // probe phases short on heavily frozen spaces (the launch path).
        let mut order: Vec<usize> =
            (0..AXES.len()).filter(|a| self.space.axis_len(*a) > 1).collect();
        self.rng.shuffle(&mut order);
        if order.is_empty() {
            // Degenerate space: nothing to probe, exploit immediately. The
            // baseline stays infinite, so regressions never fire either —
            // a singleton space is a monitoring-only tuner.
            self.probe = None;
            self.state = TunerState::Exploit;
            self.window_fill = 0;
            self.slow_windows = 0;
            return None;
        }
        self.probe = Some(ProbeState {
            axis_order: order,
            axis_pos: 0,
            pass: 0,
            moved_this_pass: false,
            candidates: Vec::new(),
            cand_pos: 0,
            samples: Vec::new(),
            cand_means: Vec::new(),
        });
        self.arm_axis()
    }

    /// Arm the sweep of the current axis; returns the first candidate.
    fn arm_axis(&mut self) -> Option<KnobPoint> {
        let (axis, first) = {
            let p = self.probe.as_mut().expect("probe state armed");
            let axis = p.axis_order[p.axis_pos];
            p.candidates = (0..self.space.axis_len(axis)).collect();
            p.cand_pos = 0;
            p.samples.clear();
            p.cand_means.clear();
            (axis, p.candidates[0])
        };
        self.apply_axis_value(axis, first)
    }

    /// Point the harness at value `value` on `axis`, keeping the chosen
    /// coordinate elsewhere. Returns `Some` when this actually changes
    /// the applied point.
    fn apply_axis_value(&mut self, axis: usize, value: usize) -> Option<KnobPoint> {
        let mut target = self.chosen;
        target[axis] = value;
        self.set_applied(target)
    }

    fn set_applied(&mut self, target: KnobIndex) -> Option<KnobPoint> {
        if target == self.applied {
            return None;
        }
        self.applied = target;
        let point = self.space.point_at(target);
        // The change takes effect from the next step on.
        self.trajectory.push((self.steps_seen, point));
        Some(point)
    }

    fn observe_probe(&mut self, wall_s: f64) -> Option<KnobPoint> {
        let cfg = self.cfg;
        // Record the sample; decide whether the candidate is finished.
        let finished = {
            let p = self.probe.as_mut().expect("probe state present in Probe");
            p.samples.push(wall_s);
            p.samples.len() >= cfg.probe_steps
        };
        if !finished {
            return None;
        }
        // Candidate finished: log its mean, move to the next candidate or
        // settle the axis.
        let (axis, next_candidate) = {
            let p = self.probe.as_mut().expect("probe state present");
            let axis = p.axis_order[p.axis_pos];
            let mean = p.samples.iter().sum::<f64>() / p.samples.len() as f64;
            p.samples.clear();
            let value = p.candidates[p.cand_pos];
            p.cand_means.push((value, mean));
            p.cand_pos += 1;
            let next = p.candidates.get(p.cand_pos).copied();
            (axis, next)
        };
        if let Some(value) = next_candidate {
            return self.apply_axis_value(axis, value);
        }
        self.settle_axis(axis)
    }

    /// All candidates on `axis` are measured: move with hysteresis, then
    /// advance to the next axis / pass / exploit.
    fn settle_axis(&mut self, axis: usize) -> Option<KnobPoint> {
        let cfg = self.cfg;
        let (best_value, best_mean, incumbent_mean) = {
            let p = self.probe.as_ref().expect("probe state present");
            let (bv, bm) = p
                .cand_means
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(v, m)| (*v, *m))
                .expect("axis sweep measured >= 1 candidate");
            let incumbent = self.chosen[axis];
            let im = p
                .cand_means
                .iter()
                .find(|(v, _)| *v == incumbent)
                .map(|(_, m)| *m)
                .expect("incumbent value is always among the candidates");
            (bv, bm, im)
        };
        let moved = best_value != self.chosen[axis]
            && best_mean < incumbent_mean * (1.0 - cfg.hysteresis);
        let settled_mean = if moved {
            self.chosen[axis] = best_value;
            best_mean
        } else {
            incumbent_mean
        };
        {
            let p = self.probe.as_mut().expect("probe state present");
            p.moved_this_pass |= moved;
        }
        // Track the best mean seen for the chosen point: the exploit
        // baseline is the settled mean of the last axis swept.
        self.baseline = settled_mean;

        let (pass_finished, more_passes) = {
            let p = self.probe.as_mut().expect("probe state present");
            p.axis_pos += 1;
            if p.axis_pos < p.axis_order.len() {
                (false, true)
            } else {
                p.pass += 1;
                let more = p.moved_this_pass && p.pass < cfg.max_passes;
                (true, more)
            }
        };
        if !pass_finished {
            return self.arm_axis();
        }
        if more_passes {
            let mut order = {
                let p = self.probe.as_mut().expect("probe state present");
                p.axis_pos = 0;
                p.moved_this_pass = false;
                std::mem::take(&mut p.axis_order)
            };
            self.rng.shuffle(&mut order);
            self.probe.as_mut().expect("probe state present").axis_order = order;
            return self.arm_axis();
        }
        // Enter exploit on the chosen point.
        self.state = TunerState::Exploit;
        self.probe = None;
        self.window_fill = 0;
        self.slow_windows = 0;
        self.set_applied(self.chosen)
    }

    fn observe_exploit(&mut self, _wall_s: f64) -> Option<KnobPoint> {
        let cfg = self.cfg;
        self.window_fill += 1;
        if self.window_fill < cfg.probe_steps {
            return None;
        }
        // The sample already landed in the ring (observe pushes first);
        // the window is simply its newest `probe_steps` entries.
        let mean = self.ring.mean_wall(cfg.probe_steps);
        self.window_fill = 0;
        if self.baseline.is_finite() && mean > self.baseline * (1.0 + cfg.regress_threshold) {
            self.slow_windows += 1;
        } else {
            self.slow_windows = 0;
        }
        if self.slow_windows >= cfg.regress_patience {
            self.slow_windows = 0;
            return self.enter_probe();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CollectiveKind, Compression};

    fn tiny_space() -> KnobSpace {
        KnobSpace {
            bucket_mbs: vec![1.0, 4.0, 16.0],
            stripes: vec![1, 8],
            chunk_kbs: vec![256],
            collectives: vec![CollectiveKind::Ring],
            compressions: vec![Compression::None],
        }
    }

    fn fb(step: u64, wall: f64) -> StepFeedback {
        StepFeedback { step, wall_s: wall, compute_s: 0.0, comm_busy_s: 0.0, busbw_gbps: 0.0 }
    }

    /// A smooth synthetic objective with a unique optimum at
    /// (bucket 4 MB, stripes 8).
    fn objective(p: &KnobPoint) -> f64 {
        let b = (p.bucket_mb.log2() - 2.0).abs(); // min at 4 MB
        let s = if p.stripes == 8 { 0.0 } else { 0.5 };
        0.1 + 0.02 * b + s
    }

    /// Drive a tuner against the objective until exploit (or `max` steps).
    fn drive(tuner: &mut AutoTuner, max: usize) {
        for step in 0..max {
            let wall = objective(&tuner.current());
            tuner.observe(&fb(step as u64, wall));
            if tuner.state() == TunerState::Exploit {
                break;
            }
        }
    }

    #[test]
    fn converges_to_the_synthetic_optimum() {
        let mut t = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap();
        drive(&mut t, 200);
        assert_eq!(t.state(), TunerState::Exploit);
        let chosen = t.chosen();
        assert_eq!(chosen.bucket_mb, 4.0, "{chosen}");
        assert_eq!(chosen.stripes, 8, "{chosen}");
        assert!(t.trajectory().len() >= 2, "probing must have moved the applied point");
    }

    #[test]
    fn same_seed_same_feedback_identical_trajectory() {
        let mk = |seed| {
            let cfg = TunerConfig { seed, ..TunerConfig::default() };
            AutoTuner::new(tiny_space(), cfg, &KnobPoint::default_static()).unwrap()
        };
        let mut a = mk(42);
        let mut b = mk(42);
        for step in 0..120u64 {
            let wa = objective(&a.current());
            let wb = objective(&b.current());
            assert_eq!(wa, wb, "applied points diverged at step {step}");
            a.observe(&fb(step, wa));
            b.observe(&fb(step, wb));
        }
        assert_eq!(a.trajectory(), b.trajectory());
        // A different seed may (and here does) visit axes in another
        // order; the destination still matches.
        let mut c = mk(7);
        drive(&mut c, 200);
        assert_eq!(c.chosen().bucket_mb, 4.0);
        assert_eq!(c.chosen().stripes, 8);
    }

    #[test]
    fn hysteresis_blocks_marginal_moves() {
        // Two bucket values within 1% of each other: the tuner must stay
        // on the incumbent rather than flap.
        let space = KnobSpace {
            bucket_mbs: vec![4.0, 16.0],
            stripes: vec![1],
            chunk_kbs: vec![256],
            collectives: vec![CollectiveKind::Ring],
            compressions: vec![Compression::None],
        };
        let cfg = TunerConfig { hysteresis: 0.05, ..TunerConfig::default() };
        let start = KnobPoint { bucket_mb: 16.0, ..KnobPoint::default_static() };
        let mut t = AutoTuner::new(space, cfg, &start).unwrap();
        for step in 0..60u64 {
            // 4 MB is 1% faster than 16 MB — inside the hysteresis band.
            let wall = if t.current().bucket_mb == 4.0 { 0.099 } else { 0.1 };
            t.observe(&fb(step, wall));
            if t.state() == TunerState::Exploit {
                break;
            }
        }
        assert_eq!(t.state(), TunerState::Exploit);
        assert_eq!(t.chosen().bucket_mb, 16.0, "1% is inside the 5% hysteresis band");
    }

    #[test]
    fn sustained_regression_triggers_reprobe() {
        let mut t = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap();
        drive(&mut t, 200);
        assert_eq!(t.state(), TunerState::Exploit);
        assert_eq!(t.probe_phases(), 1);
        let baseline = t.baseline_s();
        assert!(baseline.is_finite() && baseline > 0.0);
        // The environment degrades 10x: within patience × window steps the
        // tuner must re-enter probe.
        let cfg = t.cfg;
        let budget = cfg.regress_patience * cfg.probe_steps + 1;
        let mut reprobed = false;
        for step in 0..budget as u64 {
            t.observe(&fb(step, baseline * 10.0));
            if t.state() == TunerState::Probe {
                reprobed = true;
                break;
            }
        }
        assert!(reprobed, "10x sustained slowdown must trigger a re-probe");
        assert_eq!(t.probe_phases(), 2);
    }

    #[test]
    fn transient_spike_does_not_reprobe() {
        let mut t = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap();
        drive(&mut t, 200);
        let baseline = t.baseline_s();
        // One slow window, then recovery: patience must absorb it.
        for step in 0..2u64 {
            t.observe(&fb(step, baseline * 10.0));
        }
        for step in 2..12u64 {
            t.observe(&fb(step, baseline));
            assert_eq!(t.state(), TunerState::Exploit, "step {step}");
        }
    }

    #[test]
    fn singleton_space_is_monitoring_only() {
        let p = KnobPoint::default_static();
        let mut t =
            AutoTuner::new(KnobSpace::singleton(p), TunerConfig::default(), &p).unwrap();
        for step in 0..20u64 {
            assert_eq!(t.observe(&fb(step, 0.1)), None);
        }
        assert_eq!(t.state(), TunerState::Exploit);
        assert_eq!(t.current(), p);
        assert_eq!(t.trajectory().len(), 1);
    }

    #[test]
    fn checkpoint_round_trips_through_json() {
        let mut t = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap();
        drive(&mut t, 200);
        let ck = t.checkpoint();
        assert_eq!(ck.chosen, t.chosen());
        assert!(ck.baseline_s.is_finite());
        assert!(ck.steps_seen > 0);
        let back = TunerCheckpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back, ck);
        // A fresh (never-probed) tuner serializes its infinite baseline
        // as null and reads back as NaN.
        let fresh = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap()
        .checkpoint();
        let j = fresh.to_json();
        assert!(j.contains("\"baseline_s\":null"), "{j}");
        assert!(TunerCheckpoint::from_json(&j).unwrap().baseline_s.is_nan());
        assert!(TunerCheckpoint::from_json("{\"chosen\":42}").is_err());
    }

    #[test]
    fn from_checkpoint_starts_at_the_chosen_point() {
        let mut t = AutoTuner::new(
            tiny_space(),
            TunerConfig::default(),
            &KnobPoint::default_static(),
        )
        .unwrap();
        drive(&mut t, 200);
        let ck = t.checkpoint();
        let warm =
            AutoTuner::from_checkpoint(tiny_space(), TunerConfig::default(), &ck).unwrap();
        assert_eq!(warm.current(), ck.chosen, "warm start must begin at the learned point");
        assert_eq!(warm.state(), TunerState::Warmup);
    }

    #[test]
    fn rejects_invalid_configs() {
        let p = KnobPoint::default_static();
        let bad = TunerConfig { probe_steps: 0, ..TunerConfig::default() };
        assert!(AutoTuner::new(KnobSpace::default(), bad, &p).is_err());
        let bad = TunerConfig { hysteresis: 1.5, ..TunerConfig::default() };
        assert!(AutoTuner::new(KnobSpace::default(), bad, &p).is_err());
        let empty = KnobSpace { bucket_mbs: vec![], ..KnobSpace::default() };
        assert!(AutoTuner::new(empty, TunerConfig::default(), &p).is_err());
    }
}
