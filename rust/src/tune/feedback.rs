//! Per-step measurement feedback — the sensor half of the control loop.
//!
//! A [`StepFeedback`] is one training step's timing summary (wall,
//! compute, collective-busy seconds, effective bus bandwidth); a
//! [`FeedbackRing`] is the bounded window the controller reads its
//! decisions from. Both trainer paths produce feedback — the emulated
//! trainer from its per-step phase timers, the `netbn launch` worker
//! from [`crate::sched::StepStats`] — and recorded runs replay through
//! the same types: `netbn tune --from-trace` loads the `step_feedback`
//! records [`crate::measure::trace`] writes and feeds them back in.

use crate::measure::trace::StepFeedbackRecord;

/// One step's timing summary, as the tuner sees it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepFeedback {
    pub step: u64,
    /// Wall-clock seconds of the whole step (the tuner's objective).
    pub wall_s: f64,
    /// Seconds of the compute/emission phase.
    pub compute_s: f64,
    /// Seconds the collective engine was busy (includes overlapped spans).
    pub comm_busy_s: f64,
    /// NCCL-convention effective bus bandwidth, Gbps (0 when unknown).
    pub busbw_gbps: f64,
}

impl StepFeedback {
    /// Build from a recorded trace record (worker identity is dropped —
    /// the replay path tunes on one worker's stream).
    pub fn from_record(r: &StepFeedbackRecord) -> StepFeedback {
        StepFeedback {
            step: r.step as u64,
            wall_s: r.wall_s,
            compute_s: r.compute_s,
            comm_busy_s: r.comm_busy_s,
            busbw_gbps: r.busbw_gbps,
        }
    }

    /// The corresponding trace record for `worker`.
    pub fn to_record(&self, worker: usize) -> StepFeedbackRecord {
        StepFeedbackRecord {
            step: self.step as u32,
            worker,
            wall_s: self.wall_s,
            compute_s: self.compute_s,
            comm_busy_s: self.comm_busy_s,
            busbw_gbps: self.busbw_gbps,
        }
    }
}

/// Bounded ring of the most recent [`StepFeedback`] samples.
#[derive(Clone, Debug)]
pub struct FeedbackRing {
    cap: usize,
    buf: Vec<StepFeedback>,
    /// Index of the oldest element once the ring is full.
    head: usize,
    /// Total samples ever pushed (not capped).
    total: u64,
}

impl FeedbackRing {
    /// A ring holding up to `cap` samples (`cap >= 1`).
    pub fn new(cap: usize) -> FeedbackRing {
        assert!(cap >= 1, "feedback ring capacity must be >= 1");
        FeedbackRing { cap, buf: Vec::with_capacity(cap), head: 0, total: 0 }
    }

    pub fn push(&mut self, fb: StepFeedback) {
        if self.buf.len() < self.cap {
            self.buf.push(fb);
        } else {
            self.buf[self.head] = fb;
            self.head = (self.head + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total samples ever pushed.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The most recent sample.
    pub fn last(&self) -> Option<&StepFeedback> {
        if self.buf.is_empty() {
            None
        } else if self.buf.len() < self.cap {
            self.buf.last()
        } else {
            Some(&self.buf[(self.head + self.cap - 1) % self.cap])
        }
    }

    /// Samples oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &StepFeedback> {
        let (wrapped, start) = self.buf.split_at(self.head);
        start.iter().chain(wrapped.iter())
    }

    /// Samples with all-time sequence number `>= seq` that are still in
    /// the ring (oldest → newest), plus the next cursor to poll from.
    ///
    /// Sequence numbers are the 0-based all-time push index, so
    /// [`FeedbackRing::total`] is always the next unseen sequence. A
    /// long-poller passes back the returned cursor and only ever copies
    /// the samples it has not seen; a reader that fell more than one
    /// capacity behind silently loses the overwritten prefix (it gets
    /// the oldest retained samples instead — no error, no duplicates).
    pub fn snapshot_since(&self, seq: u64) -> (Vec<StepFeedback>, u64) {
        let first_retained = self.total - self.buf.len() as u64;
        let skip = seq.saturating_sub(first_retained).min(self.buf.len() as u64) as usize;
        (self.iter().skip(skip).copied().collect(), self.total)
    }

    /// Mean wall seconds over the newest `n` samples (all when `n` exceeds
    /// the held count); 0 when empty.
    pub fn mean_wall(&self, n: usize) -> f64 {
        let walls: Vec<f64> = self.iter().map(|f| f.wall_s).collect();
        let take = n.min(walls.len());
        if take == 0 {
            return 0.0;
        }
        walls[walls.len() - take..].iter().sum::<f64>() / take as f64
    }

    /// Population standard deviation of wall seconds over the newest `n`.
    pub fn stddev_wall(&self, n: usize) -> f64 {
        let walls: Vec<f64> = self.iter().map(|f| f.wall_s).collect();
        let take = n.min(walls.len());
        if take == 0 {
            return 0.0;
        }
        let tail = &walls[walls.len() - take..];
        let mean = tail.iter().sum::<f64>() / take as f64;
        (tail.iter().map(|w| (w - mean) * (w - mean)).sum::<f64>() / take as f64).sqrt()
    }

    /// Mean compute seconds over the newest `n` samples; 0 when empty.
    /// In a synchronous data-parallel loop wall times equalize at the
    /// slowest rank, so compute time is the per-rank signal that actually
    /// separates a straggler from its peers.
    pub fn mean_compute(&self, n: usize) -> f64 {
        let xs: Vec<f64> = self.iter().map(|f| f.compute_s).collect();
        let take = n.min(xs.len());
        if take == 0 {
            return 0.0;
        }
        xs[xs.len() - take..].iter().sum::<f64>() / take as f64
    }
}

/// One rank's straggler verdict, scored against the cohort median.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerScore {
    /// Caller-chosen identity of the member (uid or rank).
    pub id: u64,
    /// Mean compute seconds over the scoring window.
    pub compute_s: f64,
    /// `compute_s / median(compute_s over all ranks)`; 1.0 = typical,
    /// large = straggling. 1.0 when the median is zero.
    pub score: f64,
    /// Whether `score` exceeded the caller's threshold.
    pub straggler: bool,
}

/// Score every member's ring against the cohort: each rank's mean compute
/// time over the newest `window` samples, divided by the cohort median.
/// A rank whose ratio exceeds `threshold` is flagged. Rings with no
/// samples score 1.0 (unknown ≠ straggling). Results keep input order.
pub fn straggler_scores(
    rings: &[(u64, &FeedbackRing)],
    window: usize,
    threshold: f64,
) -> Vec<StragglerScore> {
    let computes: Vec<f64> = rings.iter().map(|(_, r)| r.mean_compute(window)).collect();
    let mut sorted: Vec<f64> = computes.iter().copied().filter(|c| *c > 0.0).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = if sorted.is_empty() {
        0.0
    } else if sorted.len() % 2 == 1 {
        sorted[sorted.len() / 2]
    } else {
        (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2]) / 2.0
    };
    rings
        .iter()
        .zip(computes)
        .map(|(&(id, _), compute_s)| {
            let score = if median > 0.0 && compute_s > 0.0 { compute_s / median } else { 1.0 };
            StragglerScore { id, compute_s, score, straggler: score > threshold }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(step: u64, wall: f64) -> StepFeedback {
        StepFeedback {
            step,
            wall_s: wall,
            compute_s: wall * 0.6,
            comm_busy_s: wall * 0.3,
            busbw_gbps: 1.0,
        }
    }

    #[test]
    fn ring_keeps_newest_cap_samples() {
        let mut r = FeedbackRing::new(3);
        assert!(r.is_empty());
        for i in 0..5u64 {
            r.push(fb(i, i as f64));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.total(), 5);
        let steps: Vec<u64> = r.iter().map(|f| f.step).collect();
        assert_eq!(steps, vec![2, 3, 4]);
        assert_eq!(r.last().unwrap().step, 4);
    }

    #[test]
    fn ring_before_wraparound() {
        let mut r = FeedbackRing::new(4);
        r.push(fb(0, 1.0));
        r.push(fb(1, 3.0));
        assert_eq!(r.last().unwrap().step, 1);
        let steps: Vec<u64> = r.iter().map(|f| f.step).collect();
        assert_eq!(steps, vec![0, 1]);
    }

    #[test]
    fn window_stats() {
        let mut r = FeedbackRing::new(8);
        for (i, w) in [1.0, 2.0, 3.0, 7.0].iter().enumerate() {
            r.push(fb(i as u64, *w));
        }
        assert!((r.mean_wall(2) - 5.0).abs() < 1e-12);
        assert!((r.mean_wall(100) - 3.25).abs() < 1e-12);
        assert!((r.stddev_wall(2) - 2.0).abs() < 1e-12);
        assert_eq!(FeedbackRing::new(2).mean_wall(3), 0.0);
    }

    #[test]
    fn snapshot_since_tracks_sequence_numbers() {
        let mut r = FeedbackRing::new(4);
        let (got, next) = r.snapshot_since(0);
        assert!(got.is_empty());
        assert_eq!(next, 0);
        r.push(fb(0, 1.0));
        r.push(fb(1, 2.0));
        let (got, next) = r.snapshot_since(0);
        assert_eq!(got.iter().map(|f| f.step).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(next, 2);
        // Resuming from the returned cursor yields only the delta.
        r.push(fb(2, 3.0));
        let (got, next) = r.snapshot_since(next);
        assert_eq!(got.iter().map(|f| f.step).collect::<Vec<_>>(), vec![2]);
        assert_eq!(next, 3);
        // Cursor at (or past) the tip: empty delta, cursor unchanged.
        assert_eq!(r.snapshot_since(3).0.len(), 0);
        assert_eq!(r.snapshot_since(100), (vec![], 3));
    }

    #[test]
    fn snapshot_since_survives_wraparound() {
        let mut r = FeedbackRing::new(3);
        for i in 0..7u64 {
            r.push(fb(i, i as f64));
        }
        // Seqs 0..7 pushed; only 4, 5, 6 are retained.
        let (got, next) = r.snapshot_since(5);
        assert_eq!(got.iter().map(|f| f.step).collect::<Vec<_>>(), vec![5, 6]);
        assert_eq!(next, 7);
        // A reader that fell behind the ring gets the oldest retained
        // samples (the overwritten prefix is gone, not an error).
        let (got, next) = r.snapshot_since(1);
        assert_eq!(got.iter().map(|f| f.step).collect::<Vec<_>>(), vec![4, 5, 6]);
        assert_eq!(next, 7);
    }

    #[test]
    fn record_round_trip() {
        let f = fb(9, 0.25);
        let back = StepFeedback::from_record(&f.to_record(2));
        assert_eq!(back, f);
    }

    fn ring_with_compute(computes: &[f64]) -> FeedbackRing {
        let mut r = FeedbackRing::new(16);
        for (i, c) in computes.iter().enumerate() {
            r.push(StepFeedback {
                step: i as u64,
                wall_s: 1.0, // synchronous loop: walls equalize
                compute_s: *c,
                comm_busy_s: 0.1,
                busbw_gbps: 1.0,
            });
        }
        r
    }

    #[test]
    fn straggler_scoring_flags_the_slow_rank() {
        let fast = ring_with_compute(&[0.10, 0.11, 0.10]);
        let fast2 = ring_with_compute(&[0.10, 0.10, 0.09]);
        let slow = ring_with_compute(&[0.42, 0.40, 0.41]);
        let scores =
            straggler_scores(&[(0, &fast), (1, &fast2), (2, &slow)], 8, 2.0);
        assert_eq!(scores.len(), 3);
        assert!(!scores[0].straggler && !scores[1].straggler);
        assert!(scores[2].straggler, "{scores:?}");
        assert!(scores[2].score > 3.0, "{scores:?}");
        // Equal walls: the wall signal alone could not have separated them.
        assert!((scores[2].score / scores[0].score) > 3.0);
    }

    #[test]
    fn straggler_scoring_handles_empty_and_uniform_cohorts() {
        let empty = FeedbackRing::new(4);
        let scores = straggler_scores(&[(7, &empty)], 8, 2.0);
        assert_eq!(scores[0].score, 1.0);
        assert!(!scores[0].straggler);
        let a = ring_with_compute(&[0.2, 0.2]);
        let b = ring_with_compute(&[0.2, 0.2]);
        for s in straggler_scores(&[(0, &a), (1, &b)], 8, 2.0) {
            assert!((s.score - 1.0).abs() < 1e-9);
            assert!(!s.straggler);
        }
    }
}
