//! The typed **knob space** the autotuner searches.
//!
//! A [`KnobPoint`] is one full configuration of the communication stack —
//! bucket threshold × stripe count × stripe chunk size × collective ×
//! compression — and a [`KnobSpace`] is a finite grid over those five
//! axes with validity constraints and a deterministic enumeration order.
//! Everything downstream (the coordinate-descent controller, the analytic
//! oracle's exhaustive sweep, the launch-time knob broadcast) speaks in
//! `KnobPoint`s, so the five axis names and their value parsers live in
//! exactly one place.
//!
//! Values reuse the repo's [`FromSpec`] parsers — [`CollectiveKind`] and
//! [`Compression`] (which itself accepts every
//! [`crate::compress::CodecKind`] spelling) — so `collective=hier:4` and
//! `compression=topk:0.01` work anywhere a knob is written down, and an
//! unknown knob *name* or *value* fails with an error that lists the
//! legal choices.

use crate::config::{CollectiveKind, Compression, FromSpec};
use crate::Result;
use anyhow::{anyhow, bail, ensure};
use std::fmt;

/// The five knob axis names, in enumeration order. This is the contract
/// behind every `name=value` knob spec and every actionable error.
pub const AXES: [&str; 5] = ["bucket_mb", "stripes", "chunk_kb", "collective", "compression"];

/// One full configuration of the communication stack.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KnobPoint {
    /// DDP-style bucketizer threshold in MB (`0` = one bucket).
    pub bucket_mb: f64,
    /// Parallel transport streams per peer pair.
    pub stripes: usize,
    /// Per-stream pipelining chunk in KB.
    pub chunk_kb: usize,
    pub collective: CollectiveKind,
    pub compression: Compression,
}

/// Serialize a [`Compression`] so [`Compression::parse`] reads it back:
/// `Display` writes ratios as `"4x"`, which the parser rejects.
fn compression_spec(c: &Compression) -> String {
    match c {
        Compression::None => "none".into(),
        Compression::Ratio(r) => format!("{r}"),
        Compression::Codec(k) => k.name(),
    }
}

impl KnobPoint {
    /// The repo's static default operating point: the single-stream
    /// kernel-TCP configuration the paper measures (and the baseline the
    /// `autotune_vs_static` scenario compares against).
    pub fn default_static() -> KnobPoint {
        KnobPoint {
            bucket_mb: 25.0,
            stripes: 1,
            chunk_kb: 256,
            collective: CollectiveKind::Ring,
            compression: Compression::None,
        }
    }

    /// Canonical `name=value;...` spec — the wire format of the launch
    /// coordinator's knob broadcast. Round-trips through
    /// [`KnobPoint::parse_spec`].
    pub fn spec(&self) -> String {
        format!(
            "bucket_mb={};stripes={};chunk_kb={};collective={};compression={}",
            self.bucket_mb,
            self.stripes,
            self.chunk_kb,
            self.collective,
            compression_spec(&self.compression)
        )
    }

    /// Parse the [`KnobPoint::spec`] format. Every axis must appear
    /// exactly once; unknown names fail with the legal list. Thin alias
    /// over [`FromSpec::from_spec`].
    pub fn parse_spec(s: &str) -> Result<KnobPoint> {
        Self::from_spec(s)
    }

    fn parse_spec_impl(s: &str) -> Result<KnobPoint> {
        let mut p = KnobPoint::default_static();
        let mut seen = [false; AXES.len()];
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("knob spec needs name=value, got {part:?}"))?;
            let (name, value) = (name.trim(), value.trim());
            let axis = axis_index(name)?;
            ensure!(!seen[axis], "knob {name:?} given twice in {s:?}");
            seen[axis] = true;
            match axis {
                0 => p.bucket_mb = parse_bucket_mb(value)?,
                1 => p.stripes = parse_stripes(value)?,
                2 => p.chunk_kb = parse_chunk_kb(value)?,
                3 => {
                    p.collective = CollectiveKind::from_spec(value)
                        .map_err(|e| anyhow!("knob collective: {e}"))?
                }
                _ => p.compression = Compression::from_spec(value)?,
            }
        }
        for (axis, seen) in seen.iter().enumerate() {
            ensure!(*seen, "knob spec {s:?} is missing {}", AXES[axis]);
        }
        Ok(p)
    }
}

impl FromSpec for KnobPoint {
    const KIND: &'static str = "knob spec";
    const VALID: &'static str = "bucket_mb=<mb>;stripes=<n>;chunk_kb=<kb>;collective=<spec>;\
                                 compression=<spec> (every axis exactly once, any order)";

    /// A knob spec is a composite format, so every non-empty string is
    /// "recognized": errors come from the per-axis parsers and the
    /// exactly-once bookkeeping, which already name the failing axis.
    fn match_spec(s: &str) -> Option<Result<KnobPoint>> {
        Some(Self::parse_spec_impl(s))
    }
}

impl fmt::Display for KnobPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "bucket {} MB, striped:{} @ {} KB chunks, {}, compression {}",
            self.bucket_mb, self.stripes, self.chunk_kb, self.collective, self.compression
        )
    }
}

/// Resolve an axis name, or fail with the actionable list — the one error
/// every `--knobs`/spec path funnels through.
pub fn axis_index(name: &str) -> Result<usize> {
    AXES.iter().position(|a| *a == name).ok_or_else(|| {
        anyhow!("unknown knob {name:?}; valid knobs: {}", AXES.join(", "))
    })
}

fn parse_bucket_mb(v: &str) -> Result<f64> {
    let mb: f64 =
        v.parse().map_err(|_| anyhow!("knob bucket_mb: expected a number, got {v:?}"))?;
    ensure!(mb.is_finite() && mb >= 0.0, "knob bucket_mb: must be >= 0 and finite, got {v:?}");
    Ok(mb)
}

/// Legal `stripes` values — the one range every surface enforces.
pub const STRIPES_RANGE: std::ops::RangeInclusive<usize> = 1..=64;

fn parse_stripes(v: &str) -> Result<usize> {
    let n: usize =
        v.parse().map_err(|_| anyhow!("knob stripes: expected an integer, got {v:?}"))?;
    ensure!(
        STRIPES_RANGE.contains(&n),
        "knob stripes: must be in {}..={}, got {v:?}",
        STRIPES_RANGE.start(),
        STRIPES_RANGE.end()
    );
    Ok(n)
}

/// Legal `chunk_kb` values — the ONE range every surface (knob specs,
/// `--knobs` overrides, `netbn launch --chunk-kbs` validation) enforces.
pub const CHUNK_KB_RANGE: std::ops::RangeInclusive<usize> = 1..=65536;

fn parse_chunk_kb(v: &str) -> Result<usize> {
    let kb: usize =
        v.parse().map_err(|_| anyhow!("knob chunk_kb: expected an integer, got {v:?}"))?;
    ensure!(
        CHUNK_KB_RANGE.contains(&kb),
        "knob chunk_kb: must be in {}..={}, got {v:?}",
        CHUNK_KB_RANGE.start(),
        CHUNK_KB_RANGE.end()
    );
    Ok(kb)
}

/// A finite grid over the five knob axes.
#[derive(Clone, Debug)]
pub struct KnobSpace {
    pub bucket_mbs: Vec<f64>,
    pub stripes: Vec<usize>,
    pub chunk_kbs: Vec<usize>,
    pub collectives: Vec<CollectiveKind>,
    pub compressions: Vec<Compression>,
}

/// A coordinate into a [`KnobSpace`]: one value index per axis.
pub type KnobIndex = [usize; 5];

impl Default for KnobSpace {
    /// The calibrated default grid the scenarios search: wide enough that
    /// the optimum moves with the network rate (compression wins at
    /// 1 Gbps, striping at 100 Gbps), small enough that an exhaustive
    /// sweep stays instant.
    fn default() -> KnobSpace {
        KnobSpace {
            bucket_mbs: vec![1.0, 4.0, 16.0, 64.0],
            stripes: vec![1, 2, 4, 8, 16],
            chunk_kbs: vec![64, 256, 1024],
            collectives: vec![CollectiveKind::Ring, CollectiveKind::Hierarchical { group_size: 8 }],
            compressions: vec![Compression::None, Compression::Ratio(4.0)],
        }
    }
}

impl KnobSpace {
    /// A space with exactly one point — the degenerate grid harnesses use
    /// to freeze every axis they cannot reconfigure online.
    pub fn singleton(p: KnobPoint) -> KnobSpace {
        KnobSpace {
            bucket_mbs: vec![p.bucket_mb],
            stripes: vec![p.stripes],
            chunk_kbs: vec![p.chunk_kb],
            collectives: vec![p.collective],
            compressions: vec![p.compression],
        }
    }

    /// Validity constraints for the whole grid.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.bucket_mbs.is_empty(), "knob space: bucket_mb axis is empty");
        ensure!(!self.stripes.is_empty(), "knob space: stripes axis is empty");
        ensure!(!self.chunk_kbs.is_empty(), "knob space: chunk_kb axis is empty");
        ensure!(!self.collectives.is_empty(), "knob space: collective axis is empty");
        ensure!(!self.compressions.is_empty(), "knob space: compression axis is empty");
        for &mb in &self.bucket_mbs {
            ensure!(mb.is_finite() && mb >= 0.0, "knob space: bucket_mb {mb} must be >= 0");
        }
        for &n in &self.stripes {
            ensure!(
                STRIPES_RANGE.contains(&n),
                "knob space: stripes {n} must be in {}..={}",
                STRIPES_RANGE.start(),
                STRIPES_RANGE.end()
            );
        }
        for &kb in &self.chunk_kbs {
            ensure!(
                CHUNK_KB_RANGE.contains(&kb),
                "knob space: chunk_kb {kb} must be in {}..={}",
                CHUNK_KB_RANGE.start(),
                CHUNK_KB_RANGE.end()
            );
        }
        for c in &self.compressions {
            let r = c.ratio();
            ensure!(r.is_finite() && r >= 1.0, "knob space: compression ratio {r} must be >= 1");
        }
        Ok(())
    }

    /// Override one axis from a comma-separated value list. Unknown axis
    /// names fail with the actionable list; values go through the same
    /// parsers as [`KnobPoint::parse_spec`].
    pub fn set_axis_csv(&mut self, name: &str, csv: &str) -> Result<()> {
        let axis = axis_index(name)?;
        let parts: Vec<&str> =
            csv.split(',').map(str::trim).filter(|p| !p.is_empty()).collect();
        ensure!(!parts.is_empty(), "knob {name}: empty value list {csv:?}");
        match axis {
            0 => self.bucket_mbs = parts.iter().map(|v| parse_bucket_mb(v)).collect::<Result<_>>()?,
            1 => self.stripes = parts.iter().map(|v| parse_stripes(v)).collect::<Result<_>>()?,
            2 => self.chunk_kbs = parts.iter().map(|v| parse_chunk_kb(v)).collect::<Result<_>>()?,
            3 => {
                self.collectives = parts
                    .iter()
                    .map(|v| {
                        CollectiveKind::from_spec(v).map_err(|e| anyhow!("knob collective: {e}"))
                    })
                    .collect::<Result<_>>()?
            }
            _ => {
                self.compressions =
                    parts.iter().map(|v| Compression::from_spec(v)).collect::<Result<_>>()?
            }
        }
        Ok(())
    }

    /// Build a space from a `name=v1,v2;name=v1` spec, starting from the
    /// default grid. An empty spec is the default grid.
    pub fn parse_spec(spec: &str) -> Result<KnobSpace> {
        let mut space = KnobSpace::default();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, csv) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("knob space spec needs name=v1,v2,..., got {part:?}"))?;
            space.set_axis_csv(name.trim(), csv)?;
        }
        space.validate()?;
        Ok(space)
    }

    /// Number of values on axis `a` (index into [`AXES`]).
    pub fn axis_len(&self, a: usize) -> usize {
        match a {
            0 => self.bucket_mbs.len(),
            1 => self.stripes.len(),
            2 => self.chunk_kbs.len(),
            3 => self.collectives.len(),
            _ => self.compressions.len(),
        }
    }

    /// Total grid points (product of axis lengths).
    pub fn len(&self) -> usize {
        (0..AXES.len()).map(|a| self.axis_len(a)).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The point at a coordinate. Panics on an out-of-range index — the
    /// controller only ever constructs in-range coordinates.
    pub fn point_at(&self, idx: KnobIndex) -> KnobPoint {
        KnobPoint {
            bucket_mb: self.bucket_mbs[idx[0]],
            stripes: self.stripes[idx[1]],
            chunk_kb: self.chunk_kbs[idx[2]],
            collective: self.collectives[idx[3]],
            compression: self.compressions[idx[4]],
        }
    }

    /// Deterministic enumeration of the whole grid: axis 0 varies slowest,
    /// the compression axis fastest (odometer order) — the order the
    /// oracle's exhaustive sweep reports.
    pub fn points(&self) -> Vec<KnobPoint> {
        let mut out = Vec::with_capacity(self.len());
        let mut idx: KnobIndex = [0; 5];
        loop {
            out.push(self.point_at(idx));
            // Odometer increment, last axis fastest.
            let mut a = AXES.len();
            loop {
                if a == 0 {
                    return out;
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < self.axis_len(a) {
                    break;
                }
                idx[a] = 0;
            }
        }
    }

    /// The grid coordinate nearest to an arbitrary point: numeric axes
    /// snap to the closest value, enum axes to an exact match or value 0.
    /// This is how a harness's *current* static config becomes the
    /// tuner's starting coordinate.
    pub fn nearest_index(&self, p: &KnobPoint) -> KnobIndex {
        let nearest_f64 = |vals: &[f64], x: f64| {
            vals.iter()
                .enumerate()
                .min_by(|a, b| (a.1 - x).abs().total_cmp(&(b.1 - x).abs()))
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        let nearest_usize = |vals: &[usize], x: usize| {
            vals.iter()
                .enumerate()
                .min_by_key(|(_, v)| v.abs_diff(x))
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        [
            nearest_f64(&self.bucket_mbs, p.bucket_mb),
            nearest_usize(&self.stripes, p.stripes),
            nearest_usize(&self.chunk_kbs, p.chunk_kb),
            self.collectives.iter().position(|c| *c == p.collective).unwrap_or(0),
            nearest_f64(
                &self.compressions.iter().map(|c| c.ratio()).collect::<Vec<_>>(),
                p.compression.ratio(),
            ),
        ]
    }
}

/// Parse a repeatable `--knobs name=v1,v2,...` override list into a space
/// (CLI surface of [`KnobSpace::set_axis_csv`]).
pub fn space_from_overrides(overrides: &[(String, String)]) -> Result<KnobSpace> {
    let mut space = KnobSpace::default();
    for (name, csv) in overrides {
        space.set_axis_csv(name, csv)?;
    }
    space.validate().map_err(|e| anyhow!("invalid knob space: {e:#}"))?;
    Ok(space)
}

/// Bail helper shared by the CLI: reject an empty override value early so
/// the error names the knob rather than a parser detail.
pub fn parse_knob_override(pair: &str) -> Result<(String, String)> {
    match pair.split_once('=') {
        Some((k, v)) if !v.trim().is_empty() => Ok((k.trim().to_string(), v.trim().to_string())),
        Some((k, _)) => bail!("knob {:?} has an empty value list", k.trim()),
        None => bail!("knob override needs name=v1,v2,..., got {pair:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips() {
        let points = [
            KnobPoint::default_static(),
            KnobPoint {
                bucket_mb: 4.0,
                stripes: 8,
                chunk_kb: 64,
                collective: CollectiveKind::Hierarchical { group_size: 4 },
                compression: Compression::Ratio(4.0),
            },
            KnobPoint {
                bucket_mb: 0.0,
                stripes: 2,
                chunk_kb: 1024,
                collective: CollectiveKind::Tree,
                compression: Compression::Codec(crate::compress::CodecKind::TopK {
                    k_fraction: 0.01,
                }),
            },
        ];
        for p in points {
            let back = KnobPoint::parse_spec(&p.spec()).unwrap();
            assert_eq!(back, p, "{}", p.spec());
        }
    }

    #[test]
    fn spec_rejects_malformed() {
        assert!(KnobPoint::parse_spec("bucket_mb=1").is_err()); // missing axes
        assert!(KnobPoint::parse_spec(
            "bucket_mb=1;stripes=2;chunk_kb=64;collective=ring;compression=none;stripes=4"
        )
        .is_err()); // duplicate
        assert!(KnobPoint::parse_spec(
            "bucket_mb=1;stripes=2;chunk_kb=64;collective=butterfly;compression=none"
        )
        .is_err()); // bad collective
    }

    #[test]
    fn unknown_knob_error_lists_valid_names() {
        let err = axis_index("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        for a in AXES {
            assert!(err.contains(a), "{err} missing {a}");
        }
        let mut s = KnobSpace::default();
        let err = s.set_axis_csv("chunk_bytes", "1,2").unwrap_err().to_string();
        assert!(err.contains("chunk_bytes") && err.contains("chunk_kb"), "{err}");
    }

    #[test]
    fn knob_collective_error_lists_valid_values() {
        // The shared FromSpec error shape surfaces through the knob
        // wrapper: axis context first, then the full legal list.
        let mut s = KnobSpace::default();
        let err = s.set_axis_csv("collective", "butterfly").unwrap_err().to_string();
        assert!(err.contains("knob collective"), "{err}");
        assert!(err.contains("valid values") && err.contains("ring"), "{err}");
    }

    #[test]
    fn knob_point_implements_from_spec() {
        let p = KnobPoint::default_static();
        assert_eq!(KnobPoint::from_spec(&p.spec()).unwrap(), p);
        assert!(KnobPoint::from_spec("bucket_mb=1").is_err());
    }

    #[test]
    fn knob_values_reuse_existing_parsers() {
        // Codec spellings accepted by Compression::parse work as knob
        // values; degenerate ones are rejected by the same rules.
        let mut s = KnobSpace::default();
        s.set_axis_csv("compression", "none,fp16,topk:0.01,8").unwrap();
        assert_eq!(s.compressions.len(), 4);
        assert!((s.compressions[2].ratio() - 50.0).abs() < 1e-9);
        assert!(s.set_axis_csv("compression", "topk:0").is_err());
        assert!(s.set_axis_csv("compression", "0.5").is_err());
        s.set_axis_csv("collective", "ring,hier:4,tree").unwrap();
        assert_eq!(s.collectives[1], CollectiveKind::Hierarchical { group_size: 4 });
    }

    #[test]
    fn enumeration_is_deterministic_odometer() {
        let s = KnobSpace {
            bucket_mbs: vec![1.0, 2.0],
            stripes: vec![1],
            chunk_kbs: vec![64],
            collectives: vec![CollectiveKind::Ring],
            compressions: vec![Compression::None, Compression::Ratio(4.0)],
        };
        assert_eq!(s.len(), 4);
        let pts = s.points();
        assert_eq!(pts.len(), 4);
        // Last axis (compression) varies fastest.
        assert_eq!(pts[0].bucket_mb, 1.0);
        assert_eq!(pts[0].compression, Compression::None);
        assert_eq!(pts[1].compression, Compression::Ratio(4.0));
        assert_eq!(pts[2].bucket_mb, 2.0);
        assert_eq!(s.points(), pts, "enumeration must be reproducible");
    }

    #[test]
    fn default_space_is_valid_and_sized() {
        let s = KnobSpace::default();
        s.validate().unwrap();
        assert_eq!(s.len(), 4 * 5 * 3 * 2 * 2);
        assert_eq!(s.points().len(), s.len());
    }

    #[test]
    fn nearest_index_snaps() {
        let s = KnobSpace::default();
        let idx = s.nearest_index(&KnobPoint::default_static());
        let snapped = s.point_at(idx);
        assert_eq!(snapped.stripes, 1);
        assert_eq!(snapped.bucket_mb, 16.0); // 25 snaps to 16 on {1,4,16,64}
        assert_eq!(snapped.collective, CollectiveKind::Ring);
        assert_eq!(snapped.compression, Compression::None);
    }

    #[test]
    fn singleton_space_has_one_point() {
        let p = KnobPoint::default_static();
        let s = KnobSpace::singleton(p);
        s.validate().unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.points(), vec![p]);
        assert_eq!(s.nearest_index(&p), [0, 0, 0, 0, 0]);
    }

    #[test]
    fn space_spec_parses_and_validates() {
        let s = KnobSpace::parse_spec("bucket_mb=2,8;stripes=4").unwrap();
        assert_eq!(s.bucket_mbs, vec![2.0, 8.0]);
        assert_eq!(s.stripes, vec![4]);
        assert_eq!(s.chunk_kbs, KnobSpace::default().chunk_kbs);
        assert!(KnobSpace::parse_spec("bogus=1").is_err());
        assert!(KnobSpace::parse_spec("stripes=0").is_err());
        assert_eq!(KnobSpace::parse_spec("").unwrap().len(), KnobSpace::default().len());
    }

    #[test]
    fn knob_override_parsing() {
        assert_eq!(
            parse_knob_override("stripes=1,2").unwrap(),
            ("stripes".to_string(), "1,2".to_string())
        );
        assert!(parse_knob_override("stripes").is_err());
        assert!(parse_knob_override("stripes=").is_err());
    }
}
