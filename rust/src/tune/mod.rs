//! The online **autotuning control plane** — close the measure→adapt loop
//! across transport, scheduler and collectives.
//!
//! The paper's thesis is that the *configuration* of the communication
//! stack, not raw link capacity, decides whether training scales — and
//! this repo now has five first-class knobs (bucket threshold, stripe
//! count, stripe chunk size, collective, compression) whose optimum
//! moves with the network rate (`bucket_size_sweep` and
//! `utilization_frontier` show exactly that). Agarwal et al. ("On the
//! Utility of Gradient Compression…") make the general point: the best
//! communication strategy is setup-dependent and should be *chosen from
//! measurement*. This module does that online instead of by offline
//! sweep:
//!
//! * [`feedback`] — [`StepFeedback`] / [`FeedbackRing`]: per-step
//!   wall/compute/comm-busy/busbw samples, produced by both trainer
//!   paths and replayable from recorded `step_feedback` JSONL traces
//!   (`netbn tune --from-trace`);
//! * [`knobs`] — [`KnobPoint`] / [`KnobSpace`]: the typed five-axis
//!   grid, with validity constraints, deterministic enumeration and
//!   `name=value` specs that reuse the existing
//!   [`crate::config::Compression`] / [`crate::config::CollectiveKind`]
//!   parsers;
//! * [`controller`] — [`AutoTuner`]: the seeded warmup → probe → exploit
//!   state machine (coordinate descent with hysteresis, re-probe on
//!   sustained regression); identical seeds + identical feedback ⇒
//!   identical knob trajectories;
//! * [`oracle`] — [`OracleEnv`]: the analytic objective (the calibrated
//!   transport/overlap cost models evaluated per knob point) and its
//!   exhaustive sweep, the ground truth the `autotune_convergence` /
//!   `autotune_vs_static` / `autotune_adapt` scenarios check the tuner
//!   against.
//!
//! Harness wiring: the emulated trainer
//! ([`crate::trainer::run_emulated`], `--autotune`) tunes `bucket_mb` ×
//! `compression` per step; `netbn launch --autotune` tunes the stripe
//! `chunk_kb` — rank 0 runs the tuner and broadcasts knob changes to
//! every worker at step boundaries over the mesh control channel
//! ([`crate::net::tags::CONTROL`]), so all ranks reconfigure
//! consistently. The launch path deliberately tunes only
//! arithmetic-neutral knobs (chunking changes how bytes move, never
//! what they sum to), which is why autotuned runs stay FNV-bit-identical
//! to static runs — the e2e safety gate.

pub mod controller;
pub mod feedback;
pub mod knobs;
pub mod oracle;

pub use controller::{AutoTuner, TunerCheckpoint, TunerConfig, TunerState, TuningSummary};
pub use feedback::{straggler_scores, FeedbackRing, StepFeedback, StragglerScore};
pub use knobs::{KnobPoint, KnobSpace};
pub use oracle::{drive_until_exploit, noisy_oracle_step, OracleEnv};
