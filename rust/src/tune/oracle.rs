//! Analytic **oracle**: the repo's calibrated cost models evaluated over
//! a [`KnobSpace`].
//!
//! `oracle(rate, knobs) → step seconds` composes the pieces that already
//! mirror the mechanistic stack — [`KernelTcpModel`] /
//! [`StripedModel`] for the transport ceiling, the overlap model
//! ([`crate::sim::overlap_model`]) for bucketized compute/communication
//! overlap, plus the chunk-granularity costs and a per-collective wire
//! factor — into one deterministic objective. Two consumers:
//!
//! * the `autotune_*` scenarios drive the [`AutoTuner`] against this
//!   objective (with seeded measurement noise), then check the tuner
//!   landed within tolerance of [`OracleEnv::best`] — the exhaustive
//!   sweep over the *same* objective, so the comparison is exact;
//! * `netbn tune --oracle` prints the best knob point per rate, the
//!   offline answer to "where should this cluster be operating?".
//!
//! [`AutoTuner`]: crate::tune::AutoTuner

use super::controller::{AutoTuner, TunerState};
use super::feedback::StepFeedback;
use super::knobs::{KnobPoint, KnobSpace};
use crate::config::CollectiveKind;
use crate::models::timing::backward_trace;
use crate::models::ModelId;
use crate::net::kernel_tcp::KernelTcpModel;
use crate::net::striped::StripedModel;
use crate::sim::overlap_model::{overlap_step, Chunking, OverlapModelParams};
use crate::util::Rng;

/// The fixed (non-knob) half of the experiment point: model × cluster.
#[derive(Debug)]
pub struct OracleEnv {
    pub model: ModelId,
    pub servers: usize,
    pub gpus_per_server: usize,
    trace: crate::models::timing::StepTrace,
    /// Memoized `(rate bits, knob spec) → step seconds`. The tuner and
    /// the exhaustive sweep revisit the same points many times over; each
    /// evaluation clones the per-layer trace and replans buckets, so the
    /// cache keeps that off the scenarios' hot path. The objective is a
    /// pure function of the key, so memoization cannot change any result.
    cache: std::sync::Mutex<std::collections::HashMap<(u64, String), f64>>,
}

impl OracleEnv {
    pub fn new(model: ModelId, servers: usize, gpus_per_server: usize) -> OracleEnv {
        assert!(servers >= 1 && gpus_per_server >= 1);
        OracleEnv {
            model,
            servers,
            gpus_per_server,
            trace: backward_trace(&model.profile()),
            cache: std::sync::Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Modeled distributed step time at one knob point (memoized).
    pub fn step_time_s(&self, bandwidth_gbps: f64, k: &KnobPoint) -> f64 {
        let key = (bandwidth_gbps.to_bits(), k.spec());
        if let Some(v) = self.cache.lock().unwrap().get(&key) {
            return *v;
        }
        let v = self.compute_step_time_s(bandwidth_gbps, k);
        self.cache.lock().unwrap().insert(key, v);
        v
    }

    fn compute_step_time_s(&self, bandwidth_gbps: f64, k: &KnobPoint) -> f64 {
        let transport = if k.stripes > 1 {
            StripedModel::with_streams(k.stripes).to_kernel_model()
        } else {
            KernelTcpModel::default()
        };
        let mut p = OverlapModelParams::engine(
            self.trace.clone(),
            self.servers,
            self.gpus_per_server,
            bandwidth_gbps,
            transport,
            k.bucket_mb,
        );
        p.compression_ratio = k.compression.ratio();
        // Chunk-granularity costs belong to the striped transport only:
        // the mechanistic single-stream path (SingleStream / kernel-TCP)
        // never chunks, so a stripes=1 point must not be charged for it.
        if k.stripes > 1 {
            p.chunking = Some(Chunking::striped(k.stripes, k.chunk_kb << 10));
        }
        let (wire_factor, extra_coord_s) = collective_cost(k.collective, self.servers);
        p.wire_factor = Some(wire_factor);
        p.coord_latency_s += extra_coord_s;
        overlap_step(&p).step_time_s
    }

    /// Exhaustive sweep in [`KnobSpace::points`] order.
    pub fn sweep(&self, bandwidth_gbps: f64, space: &KnobSpace) -> Vec<(KnobPoint, f64)> {
        space
            .points()
            .into_iter()
            .map(|p| {
                let t = self.step_time_s(bandwidth_gbps, &p);
                (p, t)
            })
            .collect()
    }

    /// The best knob point at a rate (ties resolve to the earliest point
    /// in enumeration order, so the answer is deterministic).
    pub fn best(&self, bandwidth_gbps: f64, space: &KnobSpace) -> (KnobPoint, f64) {
        let mut best: Option<(KnobPoint, f64)> = None;
        for (p, t) in self.sweep(bandwidth_gbps, space) {
            match &best {
                Some((_, bt)) if t >= *bt => {}
                _ => best = Some((p, t)),
            }
        }
        best.expect("a validated knob space is non-empty")
    }
}

/// Feed the tuner one oracle-measured step: the modeled truth for the
/// currently applied point under multiplicative seeded noise. The ONE
/// definition of the noise model, shared by the `autotune_*` scenarios
/// and the determinism/convergence test suites.
pub fn noisy_oracle_step(
    tuner: &mut AutoTuner,
    env: &OracleEnv,
    bandwidth_gbps: f64,
    noise: f64,
    rng: &mut Rng,
) {
    let truth = env.step_time_s(bandwidth_gbps, &tuner.current());
    let wall = truth * (1.0 + noise * (rng.next_f64() * 2.0 - 1.0));
    let fb = StepFeedback {
        step: tuner.steps_seen(),
        wall_s: wall,
        compute_s: 0.0,
        comm_busy_s: 0.0,
        busbw_gbps: 0.0,
    };
    tuner.observe(&fb);
}

/// Drive the tuner against the oracle until it exploits: `Some(steps
/// used)` on success, `None` when the budget ran out first.
pub fn drive_until_exploit(
    tuner: &mut AutoTuner,
    env: &OracleEnv,
    bandwidth_gbps: f64,
    noise: f64,
    rng: &mut Rng,
    budget: usize,
) -> Option<usize> {
    for used in 0..budget {
        if tuner.state() == TunerState::Exploit {
            return Some(used);
        }
        noisy_oracle_step(tuner, env, bandwidth_gbps, noise, rng);
    }
    (tuner.state() == TunerState::Exploit).then_some(budget)
}

/// `(wire-byte factor per bucket, extra per-bucket coordination)` for a
/// collective over `m` network parties on a flat (non-oversubscribed)
/// cluster. The ring factor is the paper's `2(M−1)/M`; the leader-ring
/// factor sums the intra and inter phases (and pays two extra phase
/// boundaries when the hierarchy is genuinely two-tier); tree and
/// parameter-server price their critical-path wire volume.
pub fn collective_cost(kind: CollectiveKind, m: usize) -> (f64, f64) {
    if m <= 1 {
        return (0.0, 0.0);
    }
    let mf = m as f64;
    let ring = 2.0 * (mf - 1.0) / mf;
    match kind {
        CollectiveKind::Ring => (ring, 0.0),
        CollectiveKind::Hierarchical { group_size } => {
            let g = group_size.clamp(1, m);
            let groups = m.div_ceil(g);
            let gf = g as f64;
            let grf = groups as f64;
            let intra = if g > 1 { 2.0 * (gf - 1.0) / gf } else { 0.0 };
            let inter = if groups > 1 { 2.0 * (grf - 1.0) / grf } else { 0.0 };
            let extra = if groups > 1 && g > 1 {
                // Two extra phase boundaries (leader ring + broadcast).
                2.0 * KernelTcpModel::default().per_msg_overhead_s
            } else {
                0.0
            };
            (intra + inter, extra)
        }
        CollectiveKind::Tree => {
            // Up + down along ceil(log2 m) levels on the critical path.
            let levels = (mf.log2()).ceil().max(1.0);
            (2.0 * levels, 0.0)
        }
        CollectiveKind::ParameterServer => {
            // The server's NIC carries every worker's push and pull.
            (2.0 * (mf - 1.0), 0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Compression;

    fn env() -> OracleEnv {
        OracleEnv::new(ModelId::ResNet50, 8, 8)
    }

    fn point(stripes: usize, compression: Compression) -> KnobPoint {
        KnobPoint {
            bucket_mb: 16.0,
            stripes,
            chunk_kb: 256,
            collective: CollectiveKind::Ring,
            compression,
        }
    }

    #[test]
    fn striping_wins_at_high_rate() {
        // VGG16 (527 MB) at 100 Gbps: the single-stream software ceiling
        // dominates the step; eight pipelines shrink it decisively.
        let e = OracleEnv::new(ModelId::Vgg16, 8, 8);
        let single = e.step_time_s(100.0, &point(1, Compression::None));
        let striped = e.step_time_s(100.0, &point(8, Compression::None));
        assert!(striped < single * 0.9, "striped {striped} vs single {single}");
    }

    #[test]
    fn compression_wins_at_low_rate() {
        let e = env();
        let plain = e.step_time_s(1.0, &point(1, Compression::None));
        let packed = e.step_time_s(1.0, &point(1, Compression::Ratio(4.0)));
        assert!(packed < plain * 0.8, "packed {packed} vs plain {plain}");
    }

    #[test]
    fn best_dominates_the_whole_grid_and_is_deterministic() {
        let e = env();
        let space = KnobSpace::default();
        let (bp, bt) = e.best(10.0, &space);
        for (p, t) in e.sweep(10.0, &space) {
            assert!(bt <= t + 1e-15, "{bp} ({bt}) vs {p} ({t})");
        }
        let (bp2, bt2) = e.best(10.0, &space);
        assert_eq!(bp, bp2);
        assert_eq!(bt, bt2);
    }

    #[test]
    fn optimum_moves_with_the_rate() {
        // The PR's premise: the best operating point is rate-dependent.
        let e = env();
        let space = KnobSpace::default();
        let (low, _) = e.best(1.0, &space);
        let (high, _) = e.best(100.0, &space);
        assert_ne!(low, high, "1 Gbps and 100 Gbps share an optimum: {low}");
        // At 1 Gbps the wire is the bottleneck: compression must be on.
        assert!(low.compression.ratio() > 1.0, "{low}");
        // At 100 Gbps the software ceiling is: striping must be on.
        assert!(high.stripes > 1, "{high}");
    }

    #[test]
    fn collective_factors_are_sane() {
        assert_eq!(collective_cost(CollectiveKind::Ring, 1), (0.0, 0.0));
        let (ring, _) = collective_cost(CollectiveKind::Ring, 8);
        assert!((ring - 1.75).abs() < 1e-12);
        // hier with one group (g >= m) IS the flat ring.
        let (h, e) = collective_cost(CollectiveKind::Hierarchical { group_size: 8 }, 8);
        assert!((h - ring).abs() < 1e-12);
        assert_eq!(e, 0.0);
        // A genuine two-tier split costs more wire on a flat cluster.
        let (h2, e2) = collective_cost(CollectiveKind::Hierarchical { group_size: 4 }, 16);
        let (ring16, _) = collective_cost(CollectiveKind::Ring, 16);
        assert!(h2 > ring16, "{h2} vs {ring16}");
        assert!(e2 > 0.0);
        // Tree and PS grow with m.
        let (tree, _) = collective_cost(CollectiveKind::Tree, 8);
        assert!(tree > ring);
        let (ps, _) = collective_cost(CollectiveKind::ParameterServer, 8);
        assert!(ps > tree);
    }

    #[test]
    fn step_time_is_positive_and_finite_over_the_grid() {
        let e = OracleEnv::new(ModelId::Vgg16, 4, 2);
        for bw in [1.0, 25.0, 100.0] {
            for (p, t) in e.sweep(bw, &KnobSpace::default()) {
                assert!(t.is_finite() && t > 0.0, "{p} at {bw} Gbps: {t}");
            }
        }
    }
}
