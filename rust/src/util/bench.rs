//! Microbenchmark harness — a criterion stand-in for the offline build.
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::new("allreduce");
//! b.bench("ring/64MB/4w", || run_allreduce(...));
//! b.report();
//! ```
//!
//! The harness warms up, then runs timed batches until both a minimum
//! iteration count and a minimum wall time are met, and reports
//! mean/p50/p95 with outlier-robust statistics.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark's collected timings.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration, one entry per timed sample.
    pub samples: Vec<f64>,
    /// Optional throughput denominator: bytes processed per iteration.
    pub bytes_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            min_time: Duration::from_millis(300),
            max_time: Duration::from_secs(5),
        }
    }
}

/// A named group of benchmarks with a shared config.
pub struct Bench {
    group: String,
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        Bench { group: group.to_string(), cfg: BenchConfig::default(), results: Vec::new() }
    }

    pub fn with_config(group: &str, cfg: BenchConfig) -> Bench {
        Bench { group: group.to_string(), cfg, results: Vec::new() }
    }

    /// Run `f` repeatedly, timing each call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_bytes(name, None, f)
    }

    /// Like [`bench`](Self::bench) but records a throughput denominator so
    /// the report can print GB/s.
    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: Option<f64>, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            let done_iters = samples.len() >= self.cfg.min_iters;
            let done_time = start.elapsed() >= self.cfg.min_time;
            if (done_iters && done_time)
                || samples.len() >= self.cfg.max_iters
                || start.elapsed() >= self.cfg.max_time
            {
                break;
            }
        }
        self.results.push(BenchResult { name: name.to_string(), samples, bytes_per_iter: bytes });
        self.results.last().unwrap()
    }

    /// Render the group's results as an aligned table on stdout and return
    /// them (so figure benches can also persist CSV).
    pub fn report(&self) -> &[BenchResult] {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<42} {:>10} {:>10} {:>10} {:>8} {:>12}",
            "name", "mean", "p50", "p95", "iters", "throughput"
        );
        for r in &self.results {
            let s = r.summary();
            let tput = match r.bytes_per_iter {
                Some(b) if s.mean > 0.0 => format!("{:.2} GB/s", b / s.mean / 1e9),
                _ => "-".to_string(),
            };
            println!(
                "{:<42} {:>10} {:>10} {:>10} {:>8} {:>12}",
                r.name,
                super::fmt::secs(s.mean),
                super::fmt::secs(s.p50),
                super::fmt::secs(s.p95),
                s.n,
                tput
            );
        }
        &self.results
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value.
/// (std::hint::black_box is stable; thin wrapper for discoverability.)
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_min_iters() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 100,
            min_time: Duration::from_millis(1),
            max_time: Duration::from_secs(1),
        };
        let mut b = Bench::with_config("t", cfg);
        let mut n = 0u64;
        let r = b.bench("count", || {
            n = black_box(n + 1);
        });
        assert!(r.samples.len() >= 5);
    }

    #[test]
    fn respects_max_time() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 1_000_000,
            max_iters: usize::MAX,
            min_time: Duration::from_secs(60),
            max_time: Duration::from_millis(50),
        };
        let mut b = Bench::with_config("t", cfg);
        let t0 = Instant::now();
        b.bench("sleepy", || std::thread::sleep(Duration::from_millis(1)));
        assert!(t0.elapsed() < Duration::from_secs(2));
    }
}
