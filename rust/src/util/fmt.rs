//! Human-readable formatting for bytes, durations and rates — used by every
//! report the harness prints.

/// Format a byte count with binary-ish units matching the paper's usage
/// (the paper says "97 MB" meaning 1e6-based MB; we follow it).
pub fn bytes(b: f64) -> String {
    let abs = b.abs();
    if abs >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if abs >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if abs >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Format a duration given in seconds.
pub fn secs(s: f64) -> String {
    let abs = s.abs();
    if abs >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if abs >= 1.0 {
        format!("{s:.2} s")
    } else if abs >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if abs >= 1e-6 {
        format!("{:.2} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Format a rate in Gbps.
pub fn gbps(bytes_per_sec: f64) -> String {
    format!("{:.2} Gbps", bytes_per_sec * 8.0 / 1e9)
}

/// Format a ratio as a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(527e6), "527.0 MB");
        assert_eq!(bytes(12.5e9), "12.50 GB");
        assert_eq!(bytes(100.0), "100 B");
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(0.0422), "42.20 ms");
        assert_eq!(secs(2.0), "2.00 s");
        assert_eq!(secs(1.5e-6), "1.50 us");
    }

    #[test]
    fn gbps_format() {
        assert_eq!(gbps(12.5e9), "100.00 Gbps");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.5999), "60.0%");
    }
}
