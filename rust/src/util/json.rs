//! Minimal JSON *reading* for the offline build (no `serde`).
//!
//! The repo already hand-rolls JSON emission ([`crate::report::json_str`],
//! `Outcome::to_json`, `BenchReport::to_json`); this is the matching
//! decode half, sized for the shapes we actually exchange: flat-ish
//! objects of strings, numbers, booleans and nested objects. It is a
//! tokenizer, not a validator — it walks one object's top level
//! respecting string escapes and brace/bracket nesting, hands back raw
//! value slices, and offers typed parsers for the leaves. Consumers:
//! the `netbn serve` HTTP API bodies, the results/tuner store, and
//! [`crate::tune::TunerCheckpoint`].

use crate::Result;
use anyhow::{bail, ensure, Context};

/// Split the top-level entries of a JSON object into `(key, raw value)`
/// pairs. `src` must be one object (surrounding whitespace is fine);
/// values come back as raw JSON text (strings still quoted, nested
/// objects/arrays intact) for a typed parser below.
pub fn object_fields(src: &str) -> Result<Vec<(String, String)>> {
    let s = src.trim();
    ensure!(
        s.starts_with('{') && s.ends_with('}'),
        "expected a JSON object, got {:?}",
        truncate(s)
    );
    let inner = &s[1..s.len() - 1];
    let bytes = inner.as_bytes();
    let mut fields = Vec::new();
    let mut i = skip_ws(bytes, 0);
    while i < bytes.len() {
        ensure!(bytes[i] == b'"', "expected a key string at byte {i} of {:?}", truncate(inner));
        let key_end = string_end(bytes, i)?;
        let key = parse_string(&inner[i..key_end])?;
        i = skip_ws(bytes, key_end);
        ensure!(
            i < bytes.len() && bytes[i] == b':',
            "expected ':' after key {key:?} in {:?}",
            truncate(inner)
        );
        i = skip_ws(bytes, i + 1);
        let value_end = value_end(bytes, i)
            .with_context(|| format!("unterminated value for key {key:?}"))?;
        fields.push((key, inner[i..value_end].trim().to_string()));
        i = skip_ws(bytes, value_end);
        if i < bytes.len() {
            ensure!(bytes[i] == b',', "expected ',' at byte {i} of {:?}", truncate(inner));
            i = skip_ws(bytes, i + 1);
        }
    }
    Ok(fields)
}

/// The raw value for `key` among [`object_fields`] output.
pub fn get<'a>(fields: &'a [(String, String)], key: &str) -> Option<&'a str> {
    fields.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// Like [`get`] but an error naming the key when absent.
pub fn require<'a>(fields: &'a [(String, String)], key: &str) -> Result<&'a str> {
    get(fields, key).with_context(|| format!("missing JSON field {key:?}"))
}

/// Decode one raw JSON string token (quotes included) to its text.
pub fn parse_string(raw: &str) -> Result<String> {
    let s = raw.trim();
    ensure!(
        s.len() >= 2 && s.starts_with('"') && s.ends_with('"'),
        "expected a JSON string, got {:?}",
        truncate(s)
    );
    let mut out = String::with_capacity(s.len() - 2);
    let mut chars = s[1..s.len() - 1].chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('/') => out.push('/'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('b') => out.push('\u{8}'),
            Some('f') => out.push('\u{c}'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                ensure!(hex.len() == 4, "truncated \\u escape in {:?}", truncate(s));
                let code = u32::from_str_radix(&hex, 16)
                    .with_context(|| format!("bad \\u escape {hex:?}"))?;
                out.push(char::from_u32(code).with_context(|| format!("bad code point {code}"))?);
            }
            other => bail!("bad escape {other:?} in {:?}", truncate(s)),
        }
    }
    Ok(out)
}

pub fn parse_f64(raw: &str) -> Result<f64> {
    let s = raw.trim();
    if s == "null" {
        return Ok(f64::NAN);
    }
    s.parse::<f64>().with_context(|| format!("expected a number, got {:?}", truncate(s)))
}

pub fn parse_u64(raw: &str) -> Result<u64> {
    raw.trim().parse::<u64>().with_context(|| format!("expected an integer, got {:?}", truncate(raw)))
}

pub fn parse_bool(raw: &str) -> Result<bool> {
    match raw.trim() {
        "true" => Ok(true),
        "false" => Ok(false),
        other => bail!("expected a boolean, got {:?}", truncate(other)),
    }
}

/// Decode an object whose values are all strings (e.g. a `params` map)
/// into ordered pairs.
pub fn parse_str_map(raw: &str) -> Result<Vec<(String, String)>> {
    object_fields(raw)?
        .into_iter()
        .map(|(k, v)| Ok((k, parse_string(&v)?)))
        .collect()
}

fn skip_ws(bytes: &[u8], mut i: usize) -> usize {
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Index one past the closing quote of the string starting at `i`.
fn string_end(bytes: &[u8], i: usize) -> Result<usize> {
    debug_assert_eq!(bytes[i], b'"');
    let mut j = i + 1;
    while j < bytes.len() {
        match bytes[j] {
            b'\\' => j += 2,
            b'"' => return Ok(j + 1),
            _ => j += 1,
        }
    }
    bail!("unterminated string");
}

/// Index one past the end of the value starting at `i`: a string, a
/// balanced object/array, or a scalar running to the next top-level
/// `,`/`}`/`]`.
fn value_end(bytes: &[u8], i: usize) -> Result<usize> {
    ensure!(i < bytes.len(), "missing value");
    match bytes[i] {
        b'"' => string_end(bytes, i),
        b'{' | b'[' => {
            let mut depth = 0usize;
            let mut j = i;
            while j < bytes.len() {
                match bytes[j] {
                    b'"' => j = string_end(bytes, j)?.saturating_sub(1),
                    b'{' | b'[' => depth += 1,
                    b'}' | b']' => {
                        depth -= 1;
                        if depth == 0 {
                            return Ok(j + 1);
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            bail!("unbalanced object/array");
        }
        _ => {
            let mut j = i;
            while j < bytes.len() && !matches!(bytes[j], b',' | b'}' | b']') {
                j += 1;
            }
            Ok(j)
        }
    }
}

fn truncate(s: &str) -> String {
    if s.len() <= 60 {
        s.to_string()
    } else {
        let cut = (0..=60).rev().find(|c| s.is_char_boundary(*c)).unwrap_or(0);
        format!("{}…", &s[..cut])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_top_level_fields() {
        let f = object_fields(
            r#"{"a":"x","n":1.5,"flag":true,"obj":{"inner":[1,2]},"list":[{"b":"}"}]}"#,
        )
        .unwrap();
        assert_eq!(parse_string(get(&f, "a").unwrap()).unwrap(), "x");
        assert_eq!(parse_f64(get(&f, "n").unwrap()).unwrap(), 1.5);
        assert!(parse_bool(get(&f, "flag").unwrap()).unwrap());
        assert_eq!(get(&f, "obj").unwrap(), r#"{"inner":[1,2]}"#);
        // Braces inside strings don't confuse the nesting walk.
        assert_eq!(get(&f, "list").unwrap(), r#"[{"b":"}"}]"#);
        assert!(get(&f, "missing").is_none());
        assert!(require(&f, "missing").is_err());
    }

    #[test]
    fn round_trips_report_escapes() {
        // Everything crate::report::json_str emits must decode back.
        let original = "a \"quoted\" line\nwith\ttabs \\ and \u{1} control";
        let encoded = crate::report::json_str(original);
        assert_eq!(parse_string(&encoded).unwrap(), original);
    }

    #[test]
    fn parses_string_maps_in_order() {
        let m = parse_str_map(r#"{"model":"resnet50","workers":"8"}"#).unwrap();
        assert_eq!(
            m,
            vec![
                ("model".to_string(), "resnet50".to_string()),
                ("workers".to_string(), "8".to_string())
            ]
        );
        assert_eq!(parse_str_map("{}").unwrap(), vec![]);
    }

    #[test]
    fn null_number_is_nan_and_garbage_errors() {
        assert!(parse_f64("null").unwrap().is_nan());
        assert!(parse_f64("zebra").is_err());
        assert!(parse_u64("1.5").is_err());
        assert!(object_fields("[1,2]").is_err());
        assert!(object_fields(r#"{"a":"#).is_err());
        assert!(parse_string("nope").is_err());
    }
}
