//! Minimal leveled logger (the offline env has no `env_logger`). Controlled
//! by `NETBN_LOG`: either a bare level (`error|warn|info|debug|trace`,
//! default `info`) or a comma-separated filter spec with per-module rules,
//! e.g. `NETBN_LOG=striped=debug,info` — `striped` lines at debug, the
//! rest at info. Module matching is by substring of the log target, so
//! `striped` matches `net.striped` and `striped.lane`.
//!
//! Launch / elastic worker processes call [`set_identity`] at entry
//! (`rank{N}` / `uid{N}`) so the N interleaved stderr streams stay
//! attributable: every line they print is prefixed `[rank3]`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// A parsed `NETBN_LOG` spec: a default level plus `module=level` rules.
#[derive(Clone, Debug, PartialEq)]
pub struct Filter {
    pub default: Level,
    /// `(module substring, level)` in spec order; first match wins.
    pub rules: Vec<(String, Level)>,
}

impl Filter {
    /// Effective level for a log target.
    pub fn level_for(&self, module: &str) -> Level {
        for (pat, l) in &self.rules {
            if module.contains(pat.as_str()) {
                return *l;
            }
        }
        self.default
    }

    /// Loosest level any target can reach — the fast-reject threshold.
    pub fn max_level(&self) -> Level {
        self.rules.iter().map(|(_, l)| *l).fold(self.default, Level::max)
    }
}

/// Parse a `NETBN_LOG` spec: comma-separated items, each either a bare
/// level (sets the default) or `module=level`. Unparseable items are
/// ignored so a typo degrades to the default rather than panicking a
/// worker fleet at startup.
pub fn parse_spec(spec: &str) -> Filter {
    let mut f = Filter { default: Level::Info, rules: Vec::new() };
    for item in spec.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        match item.split_once('=') {
            Some((module, level)) => {
                if let Some(l) = Level::parse(level.trim()) {
                    f.rules.push((module.trim().to_string(), l));
                }
            }
            None => {
                if let Some(l) = Level::parse(item) {
                    f.default = l;
                }
            }
        }
    }
    f
}

// Fast-reject threshold: max over the filter's default + rules.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

fn filter() -> &'static Mutex<Filter> {
    static FILTER: OnceLock<Mutex<Filter>> = OnceLock::new();
    FILTER.get_or_init(|| Mutex::new(Filter { default: Level::Info, rules: Vec::new() }))
}

fn identity() -> &'static Mutex<Option<String>> {
    static IDENTITY: OnceLock<Mutex<Option<String>>> = OnceLock::new();
    IDENTITY.get_or_init(|| Mutex::new(None))
}

/// Tag every subsequent log line from this process with `[{id}]` — launch
/// workers pass `rank{N}`, elastic workers `uid{N}`, so interleaved
/// multi-process stderr stays attributable.
pub fn set_identity(id: impl Into<String>) {
    *identity().lock().unwrap_or_else(|e| e.into_inner()) = Some(id.into());
}

fn install(f: Filter) {
    MAX_LEVEL.store(f.max_level() as u8, Ordering::Relaxed);
    *filter().lock().unwrap_or_else(|e| e.into_inner()) = f;
}

/// Initialize from `NETBN_LOG`; idempotent, called lazily by `log()`.
pub fn init() {
    INIT.call_once(|| {
        // SAFETY: guarded by Once; written exactly once before any read.
        unsafe { START = Some(Instant::now()) };
        if let Ok(v) = std::env::var("NETBN_LOG") {
            install(parse_spec(&v));
        }
    });
}

/// Override the level programmatically (tests, CLI `-v`) — replaces any
/// per-module rules with a flat level.
pub fn set_level(l: Level) {
    init();
    install(Filter { default: l, rules: Vec::new() });
}

/// Current fast-reject level — the loosest level any module can log at.
pub fn level() -> Level {
    Level::from_u8(MAX_LEVEL.load(Ordering::Relaxed))
}

/// Core log call — prefer the `log_*!` macros.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    init();
    if l > level() {
        return;
    }
    if l > filter().lock().unwrap_or_else(|e| e.into_inner()).level_for(module) {
        return;
    }
    // SAFETY: START is written once inside init() before this read.
    let t = unsafe { START.expect("logger initialized") }.elapsed().as_secs_f64();
    let id = identity().lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut out = std::io::stderr().lock();
    let _ = match id {
        Some(id) => writeln!(out, "[{t:10.4}] [{id}] {} {module}: {msg}", l.as_str()),
        None => writeln!(out, "[{t:10.4}] {} {module}: {msg}", l.as_str()),
    };
}

/// `log_info!(target, "fmt", args...)`
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn parse_spec_bare_level() {
        let f = parse_spec("debug");
        assert_eq!(f.default, Level::Debug);
        assert!(f.rules.is_empty());
        assert_eq!(f.max_level(), Level::Debug);
    }

    #[test]
    fn parse_spec_per_module_rules() {
        let f = parse_spec("striped=debug,info");
        assert_eq!(f.default, Level::Info);
        assert_eq!(f.rules, vec![("striped".to_string(), Level::Debug)]);
        // Substring module matching.
        assert_eq!(f.level_for("net.striped"), Level::Debug);
        assert_eq!(f.level_for("striped.lane"), Level::Debug);
        assert_eq!(f.level_for("sched"), Level::Info);
        // Fast-reject threshold is the loosest rule.
        assert_eq!(f.max_level(), Level::Debug);
    }

    #[test]
    fn parse_spec_first_match_wins_and_junk_is_ignored() {
        let f = parse_spec("launch=trace, striped=error ,bogus=nope,warn,");
        assert_eq!(f.default, Level::Warn);
        assert_eq!(f.level_for("trainer.launch"), Level::Trace);
        assert_eq!(f.level_for("striped"), Level::Error);
        assert_eq!(f.level_for("other"), Level::Warn);
        assert_eq!(f.max_level(), Level::Trace);
    }

    #[test]
    fn quieter_module_than_default() {
        let f = parse_spec("debug,chatty=error");
        assert_eq!(f.level_for("chatty.thing"), Level::Error);
        assert_eq!(f.level_for("normal"), Level::Debug);
        assert_eq!(f.max_level(), Level::Debug);
    }
}
