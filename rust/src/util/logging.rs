//! Minimal leveled logger (the offline env has no `env_logger`). Controlled
//! by `NETBN_LOG` = `error|warn|info|debug|trace`, default `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

/// Initialize from `NETBN_LOG`; idempotent, called lazily by `log()`.
pub fn init() {
    INIT.call_once(|| {
        // SAFETY: guarded by Once; written exactly once before any read.
        unsafe { START = Some(Instant::now()) };
        if let Ok(v) = std::env::var("NETBN_LOG") {
            if let Some(l) = Level::parse(&v) {
                MAX_LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Override the level programmatically (tests, CLI `-v`).
pub fn set_level(l: Level) {
    init();
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Current maximum level.
pub fn level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Core log call — prefer the `log_*!` macros.
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    init();
    if l > level() {
        return;
    }
    // SAFETY: START is written once inside init() before this read.
    let t = unsafe { START.expect("logger initialized") }.elapsed().as_secs_f64();
    let mut out = std::io::stderr().lock();
    let _ = writeln!(out, "[{t:10.4}] {} {module}: {msg}", l.as_str());
}

/// `log_info!(target, "fmt", args...)`
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
    }
}
