//! Foundation substrates built from scratch for the offline environment:
//! PRNG (no `rand`), statistics, a criterion-style microbench harness, a
//! miniature property-testing framework (no `proptest`), leveled logging,
//! cooperative shutdown signals (no `ctrlc`), and human-readable
//! formatting helpers.

pub mod bench;
pub mod fmt;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod signal;
pub mod stats;

pub use rng::Rng;
