//! A miniature property-based testing framework (the offline environment
//! has no `proptest`). Provides generators over a seeded [`Rng`], a
//! `forall` runner with failure-case reporting, and greedy shrinking for
//! the container generators.
//!
//! ```ignore
//! prop::forall("allreduce sums", 200, |rng| {
//!     let xs = prop::vec_f32(rng, 1..=4096, 10.0);
//!     /* ... assert the invariant, return Ok(()) or Err(msg) ... */
//! });
//! ```

use super::rng::Rng;
use std::ops::RangeInclusive;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Run `cases` random trials of `prop`, each with a fresh deterministic
/// RNG derived from the property name (so failures reproduce). Panics with
/// the seed and message on the first failure.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed (debugging helper).
pub fn replay<F>(seed: u64, mut prop: F) -> PropResult
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

/// FNV-1a hash — stable name→seed derivation here, and the tensor
/// bit-pattern checksum in [`crate::trainer::launch`].
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------- generators

/// Uniform usize in an inclusive range.
pub fn usize_in(rng: &mut Rng, range: RangeInclusive<usize>) -> usize {
    rng.range_usize(*range.start(), *range.end())
}

/// Vector of f32 in `[-scale, scale)`, with length in `len`.
pub fn vec_f32(rng: &mut Rng, len: RangeInclusive<usize>, scale: f32) -> Vec<f32> {
    let n = usize_in(rng, len);
    let mut v = vec![0.0f32; n];
    rng.fill_f32(&mut v, scale);
    v
}

/// Vector that sometimes contains adversarial values (0, ±inf-adjacent
/// magnitudes, denormal-ish) — useful for codec properties.
pub fn vec_f32_edgy(rng: &mut Rng, len: RangeInclusive<usize>) -> Vec<f32> {
    let mut v = vec_f32(rng, len, 100.0);
    for x in v.iter_mut() {
        match rng.next_below(12) {
            0 => *x = 0.0,
            1 => *x = f32::MIN_POSITIVE,
            2 => *x = -f32::MIN_POSITIVE,
            3 => *x = 3.0e38,
            4 => *x = -3.0e38,
            5 => *x = 1e-30,
            _ => {}
        }
    }
    v
}

/// Random "message sizes" spanning the scales distributed training sees:
/// tiny biases (bytes) through fused buckets (tens of MB).
pub fn grad_size(rng: &mut Rng) -> usize {
    // log-uniform over [4 B, 16 MB] then 4-byte aligned.
    let lo = 2.0f64;  // log2(4)
    let hi = 24.0f64; // log2(16 MiB)
    let bits = rng.range_f64(lo, hi);
    ((2f64.powf(bits) as usize) / 4).max(1) * 4
}

/// Greedy shrink of a failing `Vec` input: try removing halves, then
/// individual elements, re-running `check` (which returns true when the
/// failure still reproduces). Returns the smallest failing input found.
pub fn shrink_vec<T: Clone, F>(mut input: Vec<T>, mut check: F) -> Vec<T>
where
    F: FnMut(&[T]) -> bool,
{
    debug_assert!(check(&input), "shrink_vec called with a passing input");
    loop {
        let mut shrunk = false;
        // Halves.
        let n = input.len();
        if n > 1 {
            for (s, e) in [(0, n / 2), (n / 2, n)] {
                let mut cand = input.clone();
                cand.drain(s..e);
                if !cand.is_empty() && check(&cand) {
                    input = cand;
                    shrunk = true;
                    break;
                }
            }
        }
        if shrunk {
            continue;
        }
        // Single elements.
        let mut i = 0;
        while i < input.len() && input.len() > 1 {
            let mut cand = input.clone();
            cand.remove(i);
            if check(&cand) {
                input = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }
        if !shrunk {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |rng| {
            let v = vec_f32(rng, 1..=16, 1.0);
            if v.len() <= 16 && !v.is_empty() {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn forall_reports_failure() {
        forall("must-fail", 10, |rng| {
            let n = usize_in(rng, 0..=100);
            if n < 90 {
                Ok(())
            } else {
                Err(format!("hit {n}"))
            }
        });
    }

    #[test]
    fn grad_size_is_aligned_and_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let s = grad_size(&mut rng);
            assert_eq!(s % 4, 0);
            assert!((4..=16 << 20).contains(&s));
        }
    }

    #[test]
    fn shrink_finds_minimal_culprit() {
        // Failure: vector contains a negative number.
        let input = vec![1.0f32, 2.0, -3.0, 4.0, 5.0, 6.0];
        let out = shrink_vec(input, |v| v.iter().any(|x| *x < 0.0));
        assert_eq!(out, vec![-3.0]);
    }

    #[test]
    fn replay_reproduces() {
        let mut seen = None;
        let seed = 0xabcdef;
        let _ = replay(seed, |rng| {
            seen = Some(rng.next_u64());
            Ok(())
        });
        let mut rng2 = Rng::new(seed);
        assert_eq!(seen.unwrap(), rng2.next_u64());
    }
}
