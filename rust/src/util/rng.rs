//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we carry our own generator:
//! **SplitMix64** for seeding and **xoshiro256++** for the stream — the
//! standard pairing (Blackman & Vigna). Deterministic seeding matters here:
//! every experiment in EXPERIMENTS.md must be reproducible from its config.

/// SplitMix64 step — used to expand a single `u64` seed into the xoshiro
/// state so that even seed=0 produces a good stream.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; plenty for synthetic workloads,
/// property-test case generation and shuffling.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit mantissa path).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in an inclusive range.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bool_with_p(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Fill a slice with uniform f32 in `[-scale, scale)` — synthetic
    /// gradient / weight material.
    pub fn fill_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = (self.next_f32() * 2.0 - 1.0) * scale;
        }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small() {
        // chi-square-ish sanity: all residues hit roughly equally.
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.next_below(7) as usize] += 1;
        }
        let expect = n as f64 / 7.0;
        for c in counts {
            assert!((c as f64 - expect).abs() < expect * 0.1, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
