//! Process shutdown signals without a `libc` crate.
//!
//! The offline build has no `signal-hook`/`ctrlc`, so this binds the C
//! `signal(2)` entry point directly (std already links libc on the
//! platforms we run on). The handler is async-signal-safe by
//! construction: it performs exactly one relaxed atomic store. Long
//! loops (`netbn serve`'s accept loop, `netbn launch`'s rendezvous and
//! wait loops) poll [`triggered`] and unwind cooperatively — draining
//! running jobs, reaping `_worker` children and flushing stores instead
//! of leaking them on Ctrl-C.

use std::sync::atomic::{AtomicBool, Ordering};

/// Set (never cleared) by the handler on SIGINT/SIGTERM.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
const SIGKILL: i32 = 9;

#[cfg(unix)]
extern "C" {
    /// `sighandler_t signal(int signum, sighandler_t handler)` — carried
    /// as `usize` because the two special handlers (`SIG_DFL`/`SIG_IGN`)
    /// are integer constants, not function pointers.
    fn signal(signum: i32, handler: usize) -> usize;
    /// `int kill(pid_t pid, int sig)` — the chaos harness's fault
    /// injector (SIGKILL a worker mid-step, no chance to clean up).
    fn kill(pid: i32, sig: i32) -> i32;
}

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    SHUTDOWN.store(true, Ordering::Relaxed);
}

/// Install the SIGINT/SIGTERM handler. Idempotent; safe to call from
/// any thread before the loops that poll [`triggered`] start.
pub fn install() {
    #[cfg(unix)]
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
}

/// SIGKILL `pid` — the abrupt, uncatchable death the crash-recovery
/// scenarios inject. A best-effort no-op off unix or on a stale pid.
pub fn kill_process(pid: u32) {
    #[cfg(unix)]
    unsafe {
        let _ = kill(pid as i32, SIGKILL);
    }
    #[cfg(not(unix))]
    let _ = pid;
}

/// Has a shutdown signal arrived since the last [`reset`]?
pub fn triggered() -> bool {
    SHUTDOWN.load(Ordering::Relaxed)
}

/// Clear the flag (tests, and re-entrant embedders that survive one
/// drain and want to watch for the next signal).
pub fn reset() {
    SHUTDOWN.store(false, Ordering::Relaxed);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    extern "C" {
        fn raise(signum: i32) -> i32;
    }

    #[test]
    fn handler_sets_flag_without_killing_the_process() {
        install();
        reset();
        assert!(!triggered());
        // With the handler installed, SIGTERM must be swallowed into the
        // flag instead of taking the default (terminate) disposition.
        unsafe {
            raise(SIGTERM);
        }
        assert!(triggered());
        reset();
        assert!(!triggered());
    }
}
