//! Statistics helpers: summaries, percentiles, linear interpolation (the
//! paper's `AddEst` is an interpolation table) and least-squares fits used
//! by the transport calibration.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `xs` need not be sorted. Returns a zeroed summary
    /// for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Percentile (0–100) of an already-sorted sample, with linear
/// interpolation between order statistics.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Piecewise-linear interpolation table `y = f(x)`, exactly the mechanism
/// the paper prescribes for `AddEst(x)` (§3.1: "empirically evaluate time
/// cost of vector-add with various vector sizes ... then use linear
/// interpolation").
#[derive(Clone, Debug)]
pub struct Interp {
    xs: Vec<f64>,
    ys: Vec<f64>,
}

impl Interp {
    /// Build from `(x, y)` points. Points are sorted by `x`; duplicate `x`
    /// keeps the later `y`. Panics on empty input.
    pub fn new(mut pts: Vec<(f64, f64)>) -> Interp {
        assert!(!pts.is_empty(), "Interp::new on empty point set");
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        pts.dedup_by(|a, b| a.0 == b.0);
        let (xs, ys) = pts.into_iter().unzip();
        Interp { xs, ys }
    }

    /// Evaluate with linear interpolation inside the hull and linear
    /// extrapolation from the last segment outside it (vector-add time is
    /// asymptotically linear in size, so extrapolation is principled).
    pub fn eval(&self, x: f64) -> f64 {
        let n = self.xs.len();
        if n == 1 {
            return self.ys[0];
        }
        // Segment index: the first i with xs[i] >= x, clamped into [1, n-1].
        let i = match self.xs.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => return self.ys[i],
            Err(i) => i.clamp(1, n - 1),
        };
        let (x0, x1) = (self.xs[i - 1], self.xs[i]);
        let (y0, y1) = (self.ys[i - 1], self.ys[i]);
        y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    }

    /// The x-knots of the table.
    pub fn knots(&self) -> &[f64] {
        &self.xs
    }
}

/// Least-squares fit of `y = a + b·x`. Returns `(a, b)`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let sx = xs.iter().sum::<f64>();
    let sy = ys.iter().sum::<f64>();
    let sxx = xs.iter().map(|x| x * x).sum::<f64>();
    let sxy = xs.iter().zip(ys).map(|(x, y)| x * y).sum::<f64>();
    let denom = n * sxx - sx * sx;
    assert!(denom.abs() > 1e-12, "degenerate x in linfit");
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Geometric mean (used for cross-model aggregate scaling factors).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn interp_exact_and_between() {
        let t = Interp::new(vec![(0.0, 0.0), (10.0, 100.0), (20.0, 150.0)]);
        assert_eq!(t.eval(10.0), 100.0);
        assert!((t.eval(5.0) - 50.0).abs() < 1e-12);
        assert!((t.eval(15.0) - 125.0).abs() < 1e-12);
    }

    #[test]
    fn interp_extrapolates_linearly() {
        let t = Interp::new(vec![(0.0, 0.0), (1.0, 2.0)]);
        assert!((t.eval(2.0) - 4.0).abs() < 1e-12);
        assert!((t.eval(-1.0) + 2.0).abs() < 1e-12);
    }

    #[test]
    fn interp_unsorted_input() {
        let t = Interp::new(vec![(10.0, 1.0), (0.0, 0.0)]);
        assert!((t.eval(5.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.5 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
