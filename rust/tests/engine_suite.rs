//! Integration suite for the scenario engine: registry discovery and
//! lookup errors, parameter-schema validation, cartesian sweep expansion,
//! thread-pool speedup, JSON output, and the golden guarantee that the
//! engine's figure path writes byte-identical CSVs to the pre-engine
//! `fig <n>` path.

use netbn::engine::{
    Outcome, ParamKind, ParamSchema, ParamSpec, Scenario, ScenarioRegistry, SweepBuilder,
};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("netbn_engine_suite_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn kv(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

#[test]
fn registry_enumerates_all_entry_points() {
    let r = ScenarioRegistry::builtin();
    // ISSUE acceptance: >= 13 scenarios — 8 figures + simulate + emulate +
    // validate + >= 2 ablation sweeps.
    assert!(r.len() >= 13, "registry has only {} scenarios", r.len());
    let names = r.names();
    for expected in [
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "simulate", "emulate",
        "validate",
    ] {
        assert!(names.contains(&expected), "missing scenario {expected}");
    }
    let ablations = names.iter().filter(|n| n.starts_with("ablate-")).count();
    assert!(ablations >= 2, "only {ablations} ablation scenarios");
}

#[test]
fn unknown_scenario_error_is_helpful() {
    let r = ScenarioRegistry::builtin();
    let err = r.get("gif1").unwrap_err().to_string();
    assert!(err.contains("gif1"), "{err}");
    // The error must list registered names so the user can self-correct.
    for name in ["fig1", "simulate", "emulate", "validate"] {
        assert!(err.contains(name), "error does not list {name}: {err}");
    }
}

#[test]
fn bad_params_are_rejected_before_execution() {
    let r = ScenarioRegistry::builtin();
    let sim = r.get("simulate").unwrap();
    // Unknown key → lists legal parameter names.
    let err = sim.run(&kv(&[("wrokers", "8")])).unwrap_err().to_string();
    assert!(err.contains("wrokers"), "{err}");
    assert!(err.contains("workers"), "{err}");
    // Bad values per kind.
    for (k, v) in [
        ("workers", "eight"),
        // > 8 workers must decompose into whole 8-GPU servers; silently
        // truncating to 8 while stamping workers=12 into the Outcome
        // would mislabel structured output.
        ("workers", "12"),
        ("bandwidth", "0"),
        ("bandwidth", "-10"),
        ("model", "alexnet"),
        ("transport", "pigeon"),
        ("compression", "0.25"),
        ("compression", "topk:0"),
    ] {
        assert!(sim.run(&kv(&[(k, v)])).is_err(), "{k}={v} should be rejected");
    }
    // Figure scenarios take no parameters at all.
    let err = r.get("fig1").unwrap().run(&kv(&[("x", "1")])).unwrap_err().to_string();
    assert!(err.contains("no parameters"), "{err}");
}

#[test]
fn simulate_accepts_named_codecs_wherever_ratios_go() {
    let r = ScenarioRegistry::builtin();
    let sim = r.get("simulate").unwrap();
    let sf = |compression: &str| {
        sim.run(&kv(&[("compression", compression), ("bandwidth", "10")]))
            .unwrap()
            .metric_value("scaling_factor")
            .unwrap()
    };
    // fp16 is exactly a 2x wire ratio; onebit exactly 32x.
    assert_eq!(sf("fp16"), sf("2"));
    assert_eq!(sf("onebit"), sf("32"));
}

#[test]
fn sweep_expansion_counts_and_determinism() {
    let r = ScenarioRegistry::builtin();
    let sim = r.get("simulate").unwrap();
    let sweep = SweepBuilder::new(sim)
        .fix("model", "vgg16")
        .axis_csv("bandwidth", "1,10,25,100")
        .axis_csv("compression", "1,2,10");
    assert_eq!(sweep.len(), 12);
    let pts = sweep.points();
    assert_eq!(pts.len(), 12);
    assert_eq!(pts, sweep.points(), "expansion must be deterministic");
    // Every point carries the fixed override.
    for p in &pts {
        assert!(p.iter().any(|(k, v)| k == "model" && v == "vgg16"));
    }
}

#[test]
fn sweep_runs_simulate_grid_with_outcomes_per_point() {
    let r = ScenarioRegistry::builtin();
    let sim = r.get("simulate").unwrap();
    let results = SweepBuilder::new(sim)
        .fix("model", "resnet50")
        .axis_csv("bandwidth", "1,10,25,100")
        .axis_csv("compression", "1,10")
        .run(4);
    assert_eq!(results.len(), 8);
    let mut sfs = Vec::new();
    for p in &results {
        let out = p.outcome.as_ref().expect("simulate points never fail");
        sfs.push(out.metric_value("scaling_factor").unwrap());
    }
    // Sanity on the physics: at equal compression, more bandwidth never
    // hurts; points are in odometer order (bw varies slowest).
    assert!(sfs[0] <= sfs[6] + 1e-9, "1 Gbps {} vs 100 Gbps {}", sfs[0], sfs[6]);
}

#[test]
fn parallel_sweep_is_measurably_faster_than_serial() {
    // A scenario whose runner sleeps: 8 points x 120 ms. Serial needs
    // >= 960 ms; four workers need ~240 ms. Sleeps (not spins) overlap
    // even on a single-core host, so the margin is wide and stable.
    let mut r = ScenarioRegistry::new();
    r.register(Scenario::from_fn(
        "nap",
        "sleeps per point",
        ParamSchema::new(vec![ParamSpec::new("point", "", ParamKind::Int, "0")]),
        "test",
        |p| {
            std::thread::sleep(Duration::from_millis(120));
            let mut out = Outcome::new();
            out.metric("point", p.get_usize("point")? as f64);
            Ok(out)
        },
    ))
    .unwrap();
    let nap = r.get("nap").unwrap();
    let grid = |n: usize| {
        SweepBuilder::new(nap)
            .axis("point", (0..8).map(|i| i.to_string()).collect())
            .run(n)
    };

    let t0 = Instant::now();
    let serial = grid(1);
    let serial_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let parallel = grid(4);
    let parallel_s = t1.elapsed().as_secs_f64();

    assert_eq!(serial.len(), 8);
    assert_eq!(parallel.len(), 8);
    for (i, p) in parallel.iter().enumerate() {
        let out = p.outcome.as_ref().unwrap();
        assert_eq!(out.metric_value("point"), Some(i as f64), "results keep point order");
    }
    assert!(serial_s >= 0.9, "serial sweep should take ~0.96s, took {serial_s}");
    assert!(
        parallel_s < serial_s * 0.7,
        "--parallel 4 not measurably faster: {parallel_s}s vs {serial_s}s serial"
    );
}

#[test]
fn golden_fig1_csv_byte_identical_to_pre_engine_path() {
    // Pre-engine path: figures::run_figure + Figure::write_csv (exactly
    // what the old `fig 1` command did).
    let old_dir = tmp_dir("old");
    let run = netbn::figures::run_figure("1").unwrap();
    for f in &run.figures {
        f.write_csv(&old_dir).unwrap();
    }
    // Engine path: registry lookup + scenario run + Outcome CSVs.
    let new_dir = tmp_dir("new");
    let outcome = ScenarioRegistry::builtin().get("fig1").unwrap().run(&[]).unwrap();
    let new_paths = outcome.write_csvs(&new_dir).unwrap();
    assert_eq!(new_paths.len(), 1);

    let old_bytes = std::fs::read(old_dir.join("fig1.csv")).unwrap();
    let new_bytes = std::fs::read(new_dir.join("fig1.csv")).unwrap();
    assert!(!old_bytes.is_empty());
    assert_eq!(old_bytes, new_bytes, "engine fig1 CSV must be byte-identical");
}

#[test]
fn outcome_json_is_machine_readable() {
    let outcome = ScenarioRegistry::builtin()
        .get("simulate")
        .unwrap()
        .run(&kv(&[("workers", "16")]))
        .unwrap();
    let j = outcome.to_json();
    for needle in [
        "\"scenario\":\"simulate\"",
        "\"mode\":\"simulate\"",
        "\"params\":{",
        "\"workers\":\"16\"",
        "\"metrics\":{",
        "\"scaling_factor\":",
        "\"wall_s\":",
    ] {
        assert!(j.contains(needle), "missing {needle} in {j}");
    }
    // Balanced braces/brackets — cheap structural sanity without a parser.
    assert_eq!(j.matches('{').count(), j.matches('}').count());
    assert_eq!(j.matches('[').count(), j.matches(']').count());
}

#[test]
fn custom_scenario_registration_is_additive() {
    // The ENGINE.md worked example, as a test: registering a scenario
    // requires no dispatch changes anywhere.
    let mut r = ScenarioRegistry::builtin();
    let before = r.len();
    r.register(Scenario::from_fn(
        "wire-time",
        "pure analytic wire time at one point",
        ParamSchema::new(vec![
            ParamSpec::new("model", "model id", ParamKind::Model, "resnet50"),
            ParamSpec::new("bandwidth", "Gbps", ParamKind::PositiveFloat, "100"),
        ]),
        "analytic",
        |p| {
            let model = p.get_model("model")?;
            let bw = p.get_f64("bandwidth")?;
            let bytes = model.profile().total_bytes() as f64;
            let mut out = Outcome::new();
            out.metric("wire_s", bytes / netbn::gbps_to_bytes_per_sec(bw));
            Ok(out)
        },
    ))
    .unwrap();
    assert_eq!(r.len(), before + 1);
    let out = r.get("wire-time").unwrap().run(&[]).unwrap();
    // §4: ResNet50 at 100 Gbps ≈ 7.8 ms.
    let wire_ms = out.metric_value("wire_s").unwrap() * 1e3;
    assert!((wire_ms - 7.8).abs() < 0.8, "{wire_ms} ms");
}
