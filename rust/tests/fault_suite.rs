//! Chaos suite — the elastic launch path's three hard promises, checked
//! against real processes and real sockets:
//!
//! 1. **Crash recovery**: SIGKILL a spawned `netbn _eworker` process
//!    mid-run; the survivors replay its shards from the checkpoint and
//!    the final FNV checksum is bit-identical to an uninterrupted run;
//! 2. **Fail fast**: with recovery disabled, a dead worker produces an
//!    error naming it well before the rendezvous timeout — no wedge;
//! 3. **Deterministic re-sharding** (property): for arbitrary join/leave
//!    schedules the elastic result equals the fixed-membership oracle,
//!    because shard gradient streams are a function of `(seed, shard)`
//!    alone, never of who computes them.

use netbn::trainer::elastic::{
    elastic_launch, expected_checksum, ElasticConfig, ElasticParams, MembershipPlan,
};
use netbn::trainer::launch::SpawnMode;
use netbn::util::prop::{forall, usize_in};
use std::time::{Duration, Instant};

/// Integration tests run as their own binary, so `current_exe` is not
/// `netbn`; point the process spawner at the real CLI binary.
fn use_real_netbn() {
    std::env::set_var("NETBN_WORKER_EXE", env!("CARGO_BIN_EXE_netbn"));
}

fn small_params() -> ElasticParams {
    ElasticParams { shards: 8, elems: 512, steps: 6, seed: 0xC4A5, ..ElasticParams::default() }
}

#[test]
fn sigkilled_process_worker_recovers_bit_identical() {
    use_real_netbn();
    let params = small_params();
    let oracle = expected_checksum(&params);
    let mut cfg = ElasticConfig::loopback(
        params,
        MembershipPlan { initial: vec![1, 2, 3], joins: vec![], leaves: vec![] },
    );
    cfg.spawn = SpawnMode::Process;
    // The coordinator SIGKILLs worker 3's real OS process once it
    // reports finishing step 2 — a crash no destructor can soften.
    cfg.fault.kill = Some((3, 2));
    let report = elastic_launch(&cfg).expect("recovery run must complete");
    assert_eq!(report.checksum, oracle, "recovered run diverged from the uninterrupted oracle");
    assert!(report.recoveries >= 1, "the kill was never observed: {report:?}");
    assert_eq!(report.final_world, 2, "the dead worker should not rejoin");
    assert_eq!(report.steps, cfg.params.steps);
}

#[test]
fn dead_worker_without_recovery_fails_fast_naming_it() {
    use_real_netbn();
    let params = small_params();
    let mut cfg = ElasticConfig::loopback(
        params,
        MembershipPlan { initial: vec![1, 2, 3], joins: vec![], leaves: vec![] },
    );
    cfg.spawn = SpawnMode::Process;
    cfg.fault.kill = Some((2, 2));
    cfg.fault.recovery = false;
    let t0 = Instant::now();
    let err = elastic_launch(&cfg).expect_err("a dead worker with recovery off must fail");
    let elapsed = t0.elapsed();
    let msg = format!("{err:#}");
    assert!(msg.contains("worker 2"), "error must name the dead worker, got: {msg}");
    assert!(
        elapsed < Duration::from_secs(20),
        "fail-fast took {elapsed:?} (rendezvous timeout is {:?})",
        cfg.params.rendezvous_timeout
    );
}

#[test]
fn resharding_is_arithmetic_neutral_for_any_schedule() {
    // Arbitrary join/leave schedules over worlds of 2..=5 (shards = 8
    // bounds the max world): the elastic checksum must equal the
    // fixed-membership oracle every time. Thread mode keeps each case to
    // sockets + threads, no process spawns.
    forall("elastic re-sharding is arithmetic-neutral", 10, |rng| {
        let world0 = usize_in(rng, 2..=4);
        let steps = usize_in(rng, 3..=6);
        let params = ElasticParams {
            shards: 8,
            elems: usize_in(rng, 64..=512),
            steps,
            seed: rng.next_below(u64::MAX),
            ..ElasticParams::default()
        };
        let mut plan = MembershipPlan {
            initial: (1..=world0 as u64).collect(),
            joins: vec![],
            leaves: vec![],
        };
        if rng.next_below(2) == 0 {
            plan.joins.push((100, usize_in(rng, 1..=steps - 1)));
        }
        if rng.next_below(2) == 0 {
            plan.leaves.push((1, usize_in(rng, 1..=steps - 1)));
        }
        let oracle = expected_checksum(&params);
        let cfg = ElasticConfig::loopback(params, plan.clone());
        let report = elastic_launch(&cfg)
            .map_err(|e| format!("elastic_launch failed for plan {plan:?}: {e:#}"))?;
        if report.checksum != oracle {
            return Err(format!(
                "plan {plan:?}: elastic checksum {:x} != oracle {oracle:x} \
                 (epochs {}, membership {:?})",
                report.checksum, report.epochs, report.membership
            ));
        }
        Ok(())
    });
}
