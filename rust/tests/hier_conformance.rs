//! Hierarchical-collective conformance: `hier:<g>` must be invisible to
//! the math across every fabric × transport combination, and — under
//! exact arithmetic — indistinguishable from the flat collectives.
//!
//! Float addition is not associative, so hier (group sums, then a leader
//! ring) and a flat ring (one sequential ring) may legitimately differ in
//! the last ulp on arbitrary inputs. The cross-check therefore uses
//! **integer-valued** f32 inputs whose sums stay far below 2^24: every
//! summation order is then exact, and bit-identity across *algorithms*
//! (ring / tree / ps / hier:g) is a hard requirement, not a tolerance.
//! On arbitrary float inputs, the suite still requires bit-identity
//! across fabrics and transports *for the same algorithm* (each
//! algorithm's reduction order is deterministic), plus agreement with the
//! serial sum within float tolerance.

use netbn::collectives::hierarchical::hier_allreduce;
use netbn::collectives::reduce::serial_sum;
use netbn::collectives::ring::ring_allreduce;
use netbn::collectives::{ps::ps_allreduce, tree::tree_allreduce};
use netbn::net::striped::{StripeConfig, StripedTransport};
use netbn::net::transport::{SingleStream, Transport, TransportFabric};
use netbn::net::Fabric;
use netbn::topology::{Cluster, Topology};
use netbn::util::{prop, Rng};
use std::thread;

const WORKERS: usize = 4;
/// Uneven length: ragged chunks in both the group and leader rings.
const LEN: usize = 1003;

fn test_stripe_cfg() -> StripeConfig {
    StripeConfig { streams: 4, chunk_bytes: 512, credit_window: 1 }
}

/// Integer-valued inputs: every f32 holds a small integer, so sums are
/// exact in any order and bit-identity across algorithms is well-defined.
fn integer_inputs() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x41e9);
    (0..WORKERS)
        .map(|_| (0..LEN).map(|_| (rng.next_below(2001) as i64 - 1000) as f32).collect())
        .collect()
}

fn float_inputs() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xf10a7);
    (0..WORKERS)
        .map(|_| {
            let mut v = vec![0.0f32; LEN];
            rng.fill_f32(&mut v, 2.0);
            v
        })
        .collect()
}

#[derive(Clone, Copy, Debug)]
enum FabricKind {
    Inproc,
    Tcp,
}

fn build_fabric(kind: FabricKind, transport: &dyn Transport) -> Box<dyn Fabric> {
    match kind {
        FabricKind::Inproc => {
            Box::new(TransportFabric::inproc(WORKERS, transport, None).unwrap())
        }
        FabricKind::Tcp => Box::new(TransportFabric::tcp(WORKERS, transport, None).unwrap()),
    }
}

/// Run one algorithm over the fabric and return every worker's result.
fn run_algo(fabric: &dyn Fabric, algo: Algo, inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    let mut handles = Vec::new();
    for (ep, mut data) in fabric.endpoints().into_iter().zip(inputs) {
        handles.push(thread::spawn(move || {
            match algo {
                Algo::Ring => {
                    let ring = Topology::new(WORKERS, 1).flat_ring();
                    ring_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                }
                Algo::Tree => {
                    let ring = Topology::new(WORKERS, 1).flat_ring();
                    tree_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                }
                Algo::Ps => {
                    let ring = Topology::new(WORKERS, 1).flat_ring();
                    ps_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                }
                Algo::Hier(g) => {
                    let cluster = Cluster::new(WORKERS, g);
                    hier_allreduce(ep.as_ref(), &cluster, 0, 0, &mut data).unwrap();
                }
            }
            data
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[derive(Clone, Copy, Debug)]
enum Algo {
    Ring,
    Tree,
    Ps,
    Hier(usize),
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Exact-arithmetic cross-check: with integer-valued inputs, hier:g is
/// bit-identical to flat ring (and tree and ps) across {inproc, tcp} ×
/// {single, striped:4} for every group size.
#[test]
fn hier_bit_identical_to_flat_collectives_on_exact_inputs() {
    let inputs = integer_inputs();
    let mut reference: Option<Vec<u32>> = None;
    let algos = [
        Algo::Ring,
        Algo::Tree,
        Algo::Ps,
        Algo::Hier(1),
        Algo::Hier(2),
        Algo::Hier(3), // ragged: groups {0,1,2} {3}
        Algo::Hier(WORKERS),
    ];
    for algo in algos {
        for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
            let single = SingleStream;
            let striped = StripedTransport::new(test_stripe_cfg());
            let transports: [(&str, &dyn Transport); 2] =
                [("single", &single), ("striped:4", &striped)];
            for (tname, transport) in transports {
                let fabric = build_fabric(fabric_kind, transport);
                let results = run_algo(fabric.as_ref(), algo, inputs.clone());
                let first = bits(&results[0]);
                for (w, r) in results.iter().enumerate() {
                    assert_eq!(
                        bits(r),
                        first,
                        "{algo:?} over {fabric_kind:?}/{tname}: rank {w} disagrees"
                    );
                }
                match &reference {
                    None => reference = Some(first),
                    Some(want) => assert_eq!(
                        &first, want,
                        "{algo:?} over {fabric_kind:?}/{tname}: differs from flat ring bits"
                    ),
                }
            }
        }
    }
    // The reference really is the sum.
    let want: Vec<u32> = bits(&serial_sum(&integer_inputs()));
    assert_eq!(reference.unwrap(), want);
}

/// On arbitrary floats, hier's reduction order is deterministic, so for a
/// FIXED group size the result is bit-identical across every fabric ×
/// transport — and close to the serial sum.
#[test]
fn hier_transport_invariant_on_float_inputs() {
    let inputs = float_inputs();
    let want = serial_sum(&inputs);
    for g in [2usize, 3] {
        let mut reference: Option<Vec<u32>> = None;
        for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
            let single = SingleStream;
            let striped = StripedTransport::new(test_stripe_cfg());
            let transports: [(&str, &dyn Transport); 2] =
                [("single", &single), ("striped:4", &striped)];
            for (tname, transport) in transports {
                let fabric = build_fabric(fabric_kind, transport);
                let results = run_algo(fabric.as_ref(), Algo::Hier(g), inputs.clone());
                let first = bits(&results[0]);
                for r in &results {
                    assert_eq!(bits(r), first, "hier:{g} {fabric_kind:?}/{tname}");
                }
                match &reference {
                    None => reference = Some(first),
                    Some(wantb) => {
                        assert_eq!(&first, wantb, "hier:{g} {fabric_kind:?}/{tname} drifted")
                    }
                }
                for (a, b) in results[0].iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "hier:{g}: {a} vs {b}");
                }
            }
        }
    }
}

/// Property test over odd/uneven group sizes and world sizes: hier always
/// matches the serial sum, all ranks bitwise-agree, and with integer
/// inputs it is bit-identical to the flat ring.
#[test]
fn property_hier_over_uneven_groups() {
    prop::forall("hier conformance over ragged shapes", 10, |rng| {
        let n = prop::usize_in(rng, 2..=5);
        let g = prop::usize_in(rng, 1..=n + 2); // deliberately allows g > n
        let len = prop::usize_in(rng, 1..=300);
        // Integer-valued inputs keep every summation order exact.
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| (rng.next_below(201) as i64 - 100) as f32).collect())
            .collect();

        let fab = netbn::net::inproc::InProcFabric::new(n);
        let cluster = Cluster::new(n, g);
        let mut handles = Vec::new();
        for (ep, mut data) in fab.endpoints().into_iter().zip(inputs.clone()) {
            handles.push(thread::spawn(move || {
                hier_allreduce(ep.as_ref(), &cluster, 0, 0, &mut data).unwrap();
                data
            }));
        }
        let hier: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        let fab2 = netbn::net::inproc::InProcFabric::new(n);
        let ring = Topology::new(n, 1).flat_ring();
        let mut handles = Vec::new();
        for (ep, mut data) in fab2.endpoints().into_iter().zip(inputs) {
            let ring = ring.clone();
            handles.push(thread::spawn(move || {
                ring_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                data
            }));
        }
        let flat: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

        let first = bits(&hier[0]);
        for (w, r) in hier.iter().enumerate() {
            if bits(r) != first {
                return Err(format!("n={n} g={g}: rank {w} bitwise-disagrees"));
            }
        }
        if first != bits(&flat[0]) {
            return Err(format!("n={n} g={g}: hier bits differ from flat ring"));
        }
        Ok(())
    });
}
