//! Integration: collectives over real TCP sockets, emulator end-to-end,
//! and the emulator-vs-simulator cross-validation — no artifacts needed.

use netbn::collectives::reduce::serial_sum;
use netbn::collectives::ring::ring_allreduce;
use netbn::collectives::tree::tree_allreduce;
use netbn::config::{Compression, ExperimentConfig, TransportKind};
use netbn::models::ModelId;
use netbn::net::{tcp::TcpFabric, Fabric};
use netbn::topology::Topology;
use netbn::trainer::{run_emulated, EmulatedRunConfig};
use netbn::util::Rng;
use std::sync::Arc;

fn run_collective<F>(n: usize, len: usize, f: F) -> Vec<Vec<f32>>
where
    F: Fn(&dyn netbn::net::Endpoint, &netbn::topology::Ring, &mut [f32]) + Send + Sync + 'static,
{
    let topo = Topology::new(n, 1);
    let ring = topo.flat_ring();
    let fabric = TcpFabric::new(n, None).unwrap();
    let eps = fabric.endpoints();
    let f = Arc::new(f);
    let mut rng = Rng::new(99);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; len];
            rng.fill_f32(&mut v, 2.0);
            v
        })
        .collect();
    let want = serial_sum(&inputs);
    let mut handles = Vec::new();
    for (ep, mut data) in eps.into_iter().zip(inputs) {
        let ring = ring.clone();
        let f = Arc::clone(&f);
        handles.push(std::thread::spawn(move || {
            f(ep.as_ref(), &ring, &mut data);
            data
        }));
    }
    let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &results {
        for (a, b) in r.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }
    results
}

#[test]
fn ring_allreduce_over_tcp_matches_serial() {
    run_collective(4, 1000, |ep, ring, data| {
        ring_allreduce(ep, ring, 0, 0, data).unwrap();
    });
}

#[test]
fn tree_allreduce_over_tcp_matches_serial() {
    run_collective(5, 333, |ep, ring, data| {
        tree_allreduce(ep, ring, 0, 0, data).unwrap();
    });
}

#[test]
fn ring_large_buffer_over_tcp() {
    // 4 MB per worker: exercises framing + chunking under real sockets.
    run_collective(3, 1_000_000, |ep, ring, data| {
        ring_allreduce(ep, ring, 0, 0, data).unwrap();
    });
}

#[test]
fn emulator_transports_ordering() {
    // At 100 Gbps: ideal transport ≥ kernel-TCP transport on scaling.
    let mk = |transport| {
        let exp = ExperimentConfig {
            model: ModelId::Vgg16,
            servers: 2,
            gpus_per_server: 1,
            bandwidth_gbps: 100.0,
            transport,
            steps: 3,
            warmup_steps: 1,
            ..Default::default()
        };
        run_emulated(&EmulatedRunConfig { exp, payload_scale: 2048.0 }).unwrap()
    };
    let ideal = mk(TransportKind::FullUtilization);
    let horovod = mk(TransportKind::KernelTcp);
    assert!(
        ideal.scaling_factor > horovod.scaling_factor,
        "{} vs {}",
        ideal.scaling_factor,
        horovod.scaling_factor
    );
}

#[test]
fn emulator_utilization_drops_with_bandwidth_under_kernel_tcp() {
    let mk = |bw| {
        let exp = ExperimentConfig {
            model: ModelId::Vgg16,
            servers: 2,
            gpus_per_server: 1,
            bandwidth_gbps: bw,
            transport: TransportKind::KernelTcp,
            steps: 3,
            warmup_steps: 1,
            ..Default::default()
        };
        run_emulated(&EmulatedRunConfig { exp, payload_scale: 2048.0 }).unwrap()
    };
    let low = mk(1.0);
    let high = mk(100.0);
    // Fig 4's shape: near-saturated at 1 Gbps, far below at 100 Gbps.
    assert!(
        low.network_utilization > high.network_utilization + 0.2,
        "low {} vs high {}",
        low.network_utilization,
        high.network_utilization
    );
}

#[test]
fn emulator_compression_recovers_scaling_at_low_bandwidth() {
    let mk = |ratio| {
        let exp = ExperimentConfig {
            model: ModelId::Vgg16,
            servers: 2,
            gpus_per_server: 1,
            bandwidth_gbps: 1.0,
            transport: TransportKind::FullUtilization,
            compression: if ratio > 1.0 { Compression::Ratio(ratio) } else { Compression::None },
            steps: 3,
            warmup_steps: 1,
            ..Default::default()
        };
        run_emulated(&EmulatedRunConfig { exp, payload_scale: 2048.0 }).unwrap()
    };
    let plain = mk(1.0);
    let x10 = mk(10.0);
    assert!(x10.scaling_factor > plain.scaling_factor + 0.1, "{} vs {}", x10.scaling_factor, plain.scaling_factor);
}

#[test]
fn emulator_agrees_with_simulator() {
    // The repo's analogue of the paper's Fig 6 validation.
    let (emulated, simulated, check) =
        netbn::figures::validate_emulator_against_sim(ModelId::ResNet50, 3, 25.0, 2048.0)
            .unwrap();
    assert!(check.pass, "emulated {emulated} vs simulated {simulated}: {}", check.detail);
}
