//! Integration: the rust runtime executes the AOT artifacts and agrees
//! with the rust-native implementations (L1 Pallas kernel ⇄ L3 hot path).
//!
//! INTENTIONAL SKIPS — recorded here per the test policy: every test in
//! this file needs two things the offline build does not have:
//!
//! 1. the AOT artifacts (`artifacts/*.hlo.txt`, `model_meta.txt`,
//!    `init_params.bin`) produced by `make artifacts`, which runs the
//!    JAX/Pallas side in `python/compile/`;
//! 2. a real PJRT backend behind the `xla` crate — the offline build links
//!    the vendored stub in `vendor/xla`, which deliberately fails at HLO
//!    parse time.
//!
//! Each test therefore *skips* (early-returns with an explanatory note on
//! stderr) when the artifacts are absent, instead of failing the suite on
//! machines that cannot produce them. With artifacts present and the real
//! `xla` crate substituted in Cargo.toml, every test runs in full.

use netbn::collectives::reduce::add_assign;
use netbn::compress::{codecs, CodecKind};
use netbn::runtime::{artifacts_dir, DeviceService, HostTensor};
use netbn::util::Rng;
use std::path::PathBuf;
use std::sync::OnceLock;

const KERNEL_N: usize = 262144;

/// The artifacts directory, or `None` when `make artifacts` has not run.
fn artifacts() -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("vecadd_1m.hlo.txt").exists() {
        Some(dir)
    } else {
        None
    }
}

/// Skip the calling test (with a reason on stderr) unless artifacts exist.
macro_rules! artifacts_or_skip {
    () => {
        match artifacts() {
            Some(dir) => dir,
            None => {
                eprintln!(
                    "skipped: AOT artifacts not found at {:?} — run `make artifacts` \
                     (and use the real `xla` crate; offline builds vendor a stub PJRT backend)",
                    artifacts_dir()
                );
                return;
            }
        }
    };
}

fn service(dir: PathBuf) -> &'static DeviceService {
    static SVC: OnceLock<DeviceService> = OnceLock::new();
    SVC.get_or_init(|| DeviceService::start(dir))
}

fn rand_vec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_f32(&mut v, scale);
    v
}

#[test]
fn vecadd_artifact_matches_rust_reducer() {
    let dir = artifacts_or_skip!();
    let h = service(dir).handle();
    let a = rand_vec(1, KERNEL_N, 5.0);
    let b = rand_vec(2, KERNEL_N, 5.0);
    let out = h
        .exec(
            "vecadd_1m",
            vec![
                HostTensor::f32(&[KERNEL_N as i64], a.clone()),
                HostTensor::f32(&[KERNEL_N as i64], b.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    let mut want = a;
    add_assign(&mut want, &b);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-5, "{g} vs {w}");
    }
}

#[test]
fn vecavg_artifact_averages() {
    let dir = artifacts_or_skip!();
    let h = service(dir).handle();
    let a = vec![2.0f32; KERNEL_N];
    let b = vec![4.0f32; KERNEL_N];
    let out = h
        .exec(
            "vecavg_1m",
            vec![
                HostTensor::f32(&[KERNEL_N as i64], a),
                HostTensor::f32(&[KERNEL_N as i64], b),
            ],
        )
        .unwrap();
    assert!(out[0].as_f32().unwrap().iter().all(|x| (*x - 3.0).abs() < 1e-6));
}

#[test]
fn quantize_artifacts_match_rust_codec() {
    let dir = artifacts_or_skip!();
    let h = service(dir).handle();
    let x = rand_vec(3, KERNEL_N, 8.0);
    let enc = h
        .exec("quant_int8_1m", vec![HostTensor::f32(&[KERNEL_N as i64], x.clone())])
        .unwrap();
    assert_eq!(enc.len(), 2, "quantize returns (scale, codes)");
    let dec = h.exec("dequant_int8_1m", vec![enc[0].clone(), enc[1].clone()]).unwrap();
    let xla_decoded = dec[0].as_f32().unwrap();

    // rust codec on the same input.
    let rust_enc = codecs::encode(CodecKind::Int8, &x, 0);
    let rust_decoded = codecs::decode(CodecKind::Int8, &rust_enc, 0).unwrap();
    // Both decode within one quantization step of the original and of
    // each other (scale formulas differ by +1e-30 only).
    let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let step = max_abs / 127.0;
    for i in 0..x.len() {
        assert!((xla_decoded[i] - x[i]).abs() <= step * 0.5 + 1e-6);
        assert!((xla_decoded[i] - rust_decoded[i]).abs() <= step + 1e-6);
    }
}

#[test]
fn topk_mask_artifact_zeroes_below_threshold() {
    let dir = artifacts_or_skip!();
    let h = service(dir).handle();
    let x = rand_vec(4, KERNEL_N, 1.0);
    let thr = 0.5f32;
    let out = h
        .exec(
            "topk_mask_1m",
            vec![
                HostTensor::f32(&[KERNEL_N as i64], x.clone()),
                HostTensor::f32(&[1], vec![thr]),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for (g, v) in got.iter().zip(&x) {
        if v.abs() >= thr {
            assert_eq!(g, v);
        } else {
            assert_eq!(*g, 0.0);
        }
    }
}

#[test]
fn model_meta_matches_rust_formula() {
    use netbn::trainer::xla::ModelMeta;
    let dir = artifacts_or_skip!();
    let meta = ModelMeta::load(&dir).unwrap();
    assert_eq!(meta.param_count, netbn::models::transformer::tiny_transformer_params());
    let (vocab, _d, _l, _h, seq) = netbn::models::transformer::tiny_transformer_dims();
    assert_eq!(meta.vocab, vocab);
    assert_eq!(meta.seq, seq);
}

#[test]
fn train_step_executes_and_loss_is_sane() {
    use netbn::trainer::xla::{load_init_params, DataGen, ModelMeta, XlaTrainer};
    let dir = artifacts_or_skip!();
    let meta = ModelMeta::load(&dir).unwrap();
    let init = load_init_params(&dir, meta.param_count).unwrap();
    let trainer = XlaTrainer::new(service(dir).handle(), meta.clone());
    let mut gen = DataGen::new(7, meta.vocab, 0.1);
    let tokens = gen.batch(meta.batch, meta.seq);
    let (loss, grads) = trainer.grad_step(&init, &tokens).unwrap();
    // Fresh model ≈ uniform predictions: loss ≈ ln(vocab).
    let uniform = (meta.vocab as f64).ln();
    assert!((loss - uniform).abs() < 1.0, "loss {loss} vs ln(vocab) {uniform}");
    assert_eq!(grads.len(), meta.param_count);
    assert!(grads.iter().all(|g| g.is_finite()));
    let gnorm: f64 = grads.iter().map(|g| (*g as f64).powi(2)).sum::<f64>().sqrt();
    assert!(gnorm > 1e-3, "gradient is numerically dead: {gnorm}");

    // SGD apply agrees with the arithmetic.
    let updated = trainer.apply(&init, &grads, 0.1).unwrap();
    for i in (0..updated.len()).step_by(50_000) {
        let want = init[i] - 0.1 * grads[i];
        assert!((updated[i] - want).abs() < 1e-6);
    }
}

#[test]
fn distributed_training_keeps_replicas_identical_and_learns() {
    use netbn::net::inproc::InProcFabric;
    use netbn::trainer::xla::{load_init_params, ModelMeta, XlaTrainer};
    let dir = artifacts_or_skip!();
    let meta = ModelMeta::load(&dir).unwrap();
    let init = load_init_params(&dir, meta.param_count).unwrap();
    let trainer = XlaTrainer::new(service(dir).handle(), meta.clone());
    let fabric = InProcFabric::new(2);
    let result = trainer
        .train_distributed(
            &fabric,
            init,
            6,
            meta.batch,
            0.2,
            42,
            netbn::config::FusionConfig::default(),
        )
        .unwrap();
    assert_eq!(result.loss_curve.len(), 6);
    assert!(
        result.loss_curve[5] < result.loss_curve[0],
        "loss did not decrease: {:?}",
        result.loss_curve
    );
    assert!(result.final_params.iter().all(|p| p.is_finite()));
}
