//! Overlap-scheduler conformance: `--overlap buckets` must be
//! **bit-identical** to `--overlap off` for every collective × fabric ×
//! transport combination — overlap changes when communication happens,
//! never the arithmetic. The scheduler guarantees this by construction
//! (same deterministic bucket plan, same FIFO collective order on the
//! engine thread); this suite is the cross-stack proof, mirroring
//! `transport_conformance.rs` one layer up.

use netbn::config::{CollectiveKind, OverlapMode};
use netbn::net::striped::{StripeConfig, StripedTransport};
use netbn::net::transport::{SingleStream, Transport, TransportFabric};
use netbn::net::Fabric;
use netbn::sched::bucket::{plan_buckets, ready_order_from_ranges, BucketPlan};
use netbn::sched::{layer_ranges, run_step, AsyncCollectiveEngine};
use netbn::util::{prop, Rng};
use std::ops::Range;
use std::thread;

const WORKERS: usize = 4;
/// Uneven length: ragged ring chunks, partial stripe chunks, uneven
/// layer ranges.
const LEN: usize = 1003;
const LAYERS: usize = 5;

/// A stripe config small enough that the test tensors genuinely stripe.
fn test_stripe_cfg() -> StripeConfig {
    StripeConfig { streams: 4, chunk_bytes: 512, credit_window: 1 }
}

#[derive(Clone, Copy, Debug)]
enum FabricKind {
    Inproc,
    Tcp,
}

fn build_fabric(kind: FabricKind, transport: &dyn Transport) -> Box<dyn Fabric> {
    match kind {
        FabricKind::Inproc => {
            Box::new(TransportFabric::inproc(WORKERS, transport, None).unwrap())
        }
        FabricKind::Tcp => Box::new(TransportFabric::tcp(WORKERS, transport, None).unwrap()),
    }
}

/// Deterministic per-rank input, shared by every combination.
fn input(rank: usize, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    Rng::new(0x0f0f ^ rank as u64).fill_f32(&mut v, 2.0);
    v
}

/// Run one overlap-scheduled step on every rank; returns each rank's
/// final (reduced) gradient.
fn run_world(
    fabric: &dyn Fabric,
    kind: CollectiveKind,
    mode: OverlapMode,
    ranges: &[Range<usize>],
    plan: &BucketPlan,
    len: usize,
) -> Vec<Vec<f32>> {
    let mut handles = Vec::new();
    for (rank, ep) in fabric.endpoints().into_iter().enumerate() {
        let ranges = ranges.to_vec();
        let plan = plan.clone();
        handles.push(thread::spawn(move || {
            let engine = AsyncCollectiveEngine::new(ep, kind);
            let mut grad = input(rank, len);
            run_step(&engine, mode, 0, &mut grad, &ranges, &plan, |_| {}).unwrap();
            grad
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The plan every combination shares: uneven layer ranges, a threshold
/// that genuinely cuts (several buckets, ragged final bucket).
fn shared_plan() -> (Vec<Range<usize>>, BucketPlan) {
    let ranges = layer_ranges(LEN, LAYERS);
    let plan = plan_buckets(&ready_order_from_ranges(&ranges), 2 * (LEN / LAYERS) * 4);
    assert!(plan.buckets.len() >= 2, "threshold must cut: {}", plan.buckets.len());
    (ranges, plan)
}

#[test]
fn overlap_bit_identical_across_collectives_fabrics_transports() {
    let (ranges, plan) = shared_plan();
    for kind in [CollectiveKind::Ring, CollectiveKind::Hierarchical { group_size: 2 }] {
        // The reference is per-collective: ring and leader-ring legally
        // differ in summation order, but within one collective every
        // fabric × transport × overlap combination must agree bit for bit.
        let mut reference: Option<Vec<u32>> = None;
        for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
            let single = SingleStream;
            let striped = StripedTransport::new(test_stripe_cfg());
            let transports: [(&str, &dyn Transport); 2] =
                [("single", &single), ("striped:4", &striped)];
            for (tname, transport) in transports {
                for mode in [OverlapMode::Off, OverlapMode::Buckets] {
                    let fabric = build_fabric(fabric_kind, transport);
                    let results =
                        run_world(fabric.as_ref(), kind, mode, &ranges, &plan, LEN);
                    let first = bits(&results[0]);
                    for (w, r) in results.iter().enumerate() {
                        assert_eq!(
                            bits(r),
                            first,
                            "{kind:?}/{fabric_kind:?}/{tname}/{mode:?}: rank {w} disagrees"
                        );
                    }
                    match &reference {
                        None => reference = Some(first),
                        Some(want) => assert_eq!(
                            &first, want,
                            "{kind:?}/{fabric_kind:?}/{tname}/{mode:?}: differs from reference"
                        ),
                    }
                }
            }
        }
    }
}

#[test]
fn overlap_matches_reference_sum() {
    // Not just self-consistent: the reduced values equal a directly
    // computed elementwise sum of the inputs, within f32 tolerance.
    let (ranges, plan) = shared_plan();
    let mut want = vec![0.0f32; LEN];
    for rank in 0..WORKERS {
        for (w, x) in want.iter_mut().zip(&input(rank, LEN)) {
            *w += *x;
        }
    }
    let fabric = build_fabric(FabricKind::Inproc, &SingleStream);
    let results = run_world(
        fabric.as_ref(),
        CollectiveKind::Ring,
        OverlapMode::Buckets,
        &ranges,
        &plan,
        LEN,
    );
    for r in &results {
        for (a, b) in r.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{a} vs {b}");
        }
    }
}

#[test]
fn property_uneven_boundaries_stay_bit_identical() {
    // Random layer counts, random (ragged) gradient lengths, random
    // thresholds — including thresholds smaller than one layer and larger
    // than the whole tensor: blocking and overlapped must agree bitwise.
    prop::forall("overlap == blocking over uneven bucket/layer boundaries", 12, |rng| {
        let len = prop::usize_in(rng, 64..=1500);
        let layers = prop::usize_in(rng, 1..=len.min(9));
        let ranges = layer_ranges(len, layers);
        let threshold = prop::usize_in(rng, 1..=len * 8);
        let plan = plan_buckets(&ready_order_from_ranges(&ranges), threshold);
        let run = |mode: OverlapMode| {
            let fabric = build_fabric(FabricKind::Inproc, &SingleStream);
            run_world(fabric.as_ref(), CollectiveKind::Ring, mode, &ranges, &plan, len)
        };
        let off = run(OverlapMode::Off);
        let on = run(OverlapMode::Buckets);
        for (rank, (a, b)) in off.iter().zip(&on).enumerate() {
            if bits(a) != bits(b) {
                return Err(format!(
                    "rank {rank} differs (len {len}, layers {layers}, threshold {threshold})"
                ));
            }
        }
        Ok(())
    });
}
