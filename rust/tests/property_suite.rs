//! Cross-module property tests: invariants that must hold across
//! algorithms, codecs, the simulator and the parsers — the "extensive
//! tests" layer above per-module unit tests.

use netbn::collectives::reduce::serial_sum;
use netbn::collectives::{ps::ps_allreduce, ring::ring_allreduce, tree::tree_allreduce};
use netbn::compress::{codecs, CodecKind};
use netbn::models::timing::backward_trace;
use netbn::models::ModelId;
use netbn::net::{inproc::InProcFabric, Endpoint, Fabric};
use netbn::sim::{simulate, SimParams};
use netbn::topology::{Ring, Topology};
use netbn::util::prop;

type Collective = fn(&dyn Endpoint, &Ring, u32, u32, &mut [f32]) -> netbn::Result<()>;

fn run_collective(inputs: Vec<Vec<f32>>, f: Collective) -> Vec<Vec<f32>> {
    let n = inputs.len();
    let topo = Topology::new(n, 1);
    let ring = topo.flat_ring();
    let fabric = InProcFabric::new(n);
    let eps = fabric.endpoints();
    let mut handles = Vec::new();
    for (ep, mut data) in eps.into_iter().zip(inputs) {
        let ring = ring.clone();
        handles.push(std::thread::spawn(move || {
            f(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
            data
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn all_three_collectives_agree_with_each_other() {
    prop::forall("ring == tree == ps == serial", 10, |rng| {
        let n = prop::usize_in(rng, 2..=5);
        let len = prop::usize_in(rng, 1..=200);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| prop::vec_f32(rng, len..=len, 2.0)).collect();
        let want = serial_sum(&inputs);
        for (name, f) in [
            ("ring", ring_allreduce as Collective),
            ("tree", tree_allreduce as Collective),
            ("ps", ps_allreduce as Collective),
        ] {
            for (w, r) in run_collective(inputs.clone(), f).into_iter().enumerate() {
                for i in 0..want.len() {
                    if (r[i] - want[i]).abs() > 1e-3 {
                        return Err(format!("{name} worker {w} elem {i}: {} vs {}", r[i], want[i]));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn codec_round_trip_structural_invariants() {
    // Length preserved; decode(encode(x)) error bounded by codec class.
    prop::forall("codec round-trip invariants", 40, |rng| {
        let xs = prop::vec_f32(rng, 1..=2000, 5.0);
        let norm = xs.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-9);
        for kind in [
            CodecKind::Fp16,
            CodecKind::Int8,
            CodecKind::TopK { k_fraction: 0.5 },
            CodecKind::RandomK { k_fraction: 0.5 },
            CodecKind::OneBit,
        ] {
            let enc = codecs::encode(kind, &xs, 11);
            let dec = codecs::decode(kind, &enc, 11).map_err(|e| format!("{kind:?}: {e}"))?;
            if dec.len() != xs.len() {
                return Err(format!("{kind:?} changed length"));
            }
            let err =
                xs.iter().zip(&dec).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt();
            // Generous class bound: quantizers ≤ 10% rel error, sparse/sign
            // codecs never exceed ~2 norms (1-bit worst case flips values;
            // randk scales kept coords by 1/k = 2×).
            let bound = match kind {
                CodecKind::Fp16 => 0.01 * norm,
                CodecKind::Int8 => 0.10 * norm,
                _ => 2.0 * norm,
            };
            if err > bound {
                return Err(format!("{kind:?}: err {err} > bound {bound}"));
            }
            // Codecs with a real nominal ratio must actually be smaller
            // for big buffers (topk@50% is nominally 1.0× — value+index
            // per kept coordinate — and exempt).
            if kind.nominal_ratio() >= 1.5 && xs.len() > 500 && enc.bytes.len() >= xs.len() * 4 {
                return Err(format!("{kind:?} did not compress"));
            }
        }
        Ok(())
    });
}

#[test]
fn decode_rejects_corrupt_payloads() {
    prop::forall("codec decode handles truncation", 30, |rng| {
        let xs = prop::vec_f32(rng, 8..=256, 1.0);
        for kind in [CodecKind::Fp16, CodecKind::Int8, CodecKind::TopK { k_fraction: 0.25 }] {
            let mut enc = codecs::encode(kind, &xs, 0);
            let cut = prop::usize_in(rng, 0..=enc.bytes.len().saturating_sub(1));
            enc.bytes.truncate(cut);
            // Must error, never panic or return wrong-length data.
            if let Ok(dec) = codecs::decode(kind, &enc, 0) {
                if dec.len() != xs.len() {
                    return Err(format!("{kind:?}: truncated decode changed length"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn simulator_monotonicity_properties() {
    prop::forall("sim monotone in bw / compression / servers", 25, |rng| {
        let id = *rng.choose(&ModelId::paper_models());
        let trace = backward_trace(&id.profile());
        let servers = prop::usize_in(rng, 2..=8);
        let bw = rng.range_f64(1.0, 100.0);

        // More bandwidth never hurts.
        let f_lo = simulate(&SimParams::whatif(trace.clone(), servers, 8, bw)).scaling_factor;
        let f_hi =
            simulate(&SimParams::whatif(trace.clone(), servers, 8, bw * 2.0)).scaling_factor;
        if f_hi + 1e-9 < f_lo {
            return Err(format!("{id} {servers}s: bw {bw}->{} lowered sf {f_lo}->{f_hi}", bw * 2.0));
        }
        // Compression never hurts (in the what-if model).
        let mut p = SimParams::whatif(trace.clone(), servers, 8, bw);
        p.compression_ratio = rng.range_f64(1.0, 50.0);
        let f_c = simulate(&p).scaling_factor;
        if f_c + 1e-9 < f_lo {
            return Err(format!("compression lowered sf {f_lo}->{f_c}"));
        }
        // Scaling factor is a valid fraction and overhead non-negative.
        let r = simulate(&SimParams::horovod_like(trace, servers, 8, bw));
        if !(0.0..=1.0 + 1e-9).contains(&r.scaling_factor) || r.t_overhead < -1e-12 {
            return Err(format!("invalid result {r:?}"));
        }
        Ok(())
    });
}

#[test]
fn simulator_more_servers_never_scale_better() {
    prop::forall("sim monotone in servers", 20, |rng| {
        let id = *rng.choose(&ModelId::paper_models());
        let trace = backward_trace(&id.profile());
        let bw = rng.range_f64(1.0, 100.0);
        let mut last = f64::INFINITY;
        for servers in [2usize, 4, 8] {
            let f = simulate(&SimParams::horovod_like(trace.clone(), servers, 8, bw))
                .scaling_factor;
            if f > last + 1e-9 {
                return Err(format!("{id} @{bw}: {servers} servers scaled better ({f} > {last})"));
            }
            last = f;
        }
        Ok(())
    });
}

#[test]
fn config_parser_never_panics_on_garbage() {
    prop::forall("config parser total", 200, |rng| {
        let len = prop::usize_in(rng, 0..=120);
        let charset: Vec<char> =
            "abcdefgh =[]#\"0123456789._-\n\tservers model fusion".chars().collect();
        let text: String = (0..len).map(|_| *rng.choose(&charset)).collect();
        // Must return Ok or Err, never panic.
        let _ = netbn::config::parser::parse(&text);
        let _ = netbn::config::parser::experiment_from_str(&text);
        Ok(())
    });
}

#[test]
fn trace_records_round_trip_through_jsonl() {
    use netbn::measure::TraceRecord;
    prop::forall("trace jsonl round-trip", 100, |rng| {
        let rec = TraceRecord {
            kind: ["grad_ready", "bucket_emit", "allreduce_done"][rng.next_below(3) as usize]
                .to_string(),
            step: rng.next_below(10_000) as u32,
            worker: prop::usize_in(rng, 0..=63),
            id: prop::usize_in(rng, 0..=400),
            bytes: prop::usize_in(rng, 0..=1 << 30),
            t: rng.range_f64(0.0, 1e4),
        };
        let back = TraceRecord::from_json_line(&rec.to_json_line())
            .map_err(|e| format!("parse: {e}"))?;
        if back != rec {
            return Err(format!("{back:?} != {rec:?}"));
        }
        Ok(())
    });
}

#[test]
fn fusion_timeline_and_sim_agree_on_bucket_count() {
    // The emulator's precomputed timeline and the simulator's internal
    // fusion pass must make identical fusion decisions (same state
    // machine, same trace) — this is the invariant that keeps the two
    // clock domains comparable.
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let timeline =
            netbn::trainer::bucket_timeline(&trace, netbn::config::FusionConfig::default());
        let sim = simulate(&SimParams::whatif(trace, 8, 8, 100.0));
        assert_eq!(timeline.len(), sim.buckets, "{id}");
    }
}

#[test]
fn span_codec_round_trips_random_batches() {
    use netbn::obs::{span, SpanRecord};
    prop::forall("span wire codec round-trip", 60, |rng| {
        let names =
            ["step.total", "wire.send", "reduce.add", "x", "a.very.long.span.name.for.framing"];
        let n = prop::usize_in(rng, 0..=64);
        let batch: Vec<SpanRecord> = (0..n)
            .map(|_| SpanRecord {
                seq: rng.next_u64(),
                name: (*rng.choose(&names)).to_string(),
                rank: rng.next_u64() as u32,
                step: rng.next_u64() as u32,
                start_us: rng.next_u64(),
                dur_us: rng.next_u64(),
                bytes: rng.next_u64(),
            })
            .collect();
        let wire = span::encode(&batch);
        let back = span::decode(&wire).map_err(|e| format!("decode: {e}"))?;
        if back != batch {
            return Err(format!("round-trip changed {} records", batch.len()));
        }
        // Any strict prefix must error (the count header promises more
        // bytes than remain), and so must trailing garbage — never panic,
        // never silently return a short batch.
        let cut = prop::usize_in(rng, 0..=wire.len() - 1);
        if span::decode(&wire[..cut]).is_ok() {
            return Err(format!("decode accepted a {cut}-byte prefix of {}", wire.len()));
        }
        let mut extra = wire.clone();
        extra.push(rng.next_u64() as u8);
        if span::decode(&extra).is_ok() {
            return Err("decode accepted trailing bytes".to_string());
        }
        Ok(())
    });
}

#[test]
fn span_ring_wraparound_keeps_cursors_consistent() {
    use netbn::obs::span;
    // The ring is process-global: serialize with anything else that
    // enables the tracer in this test binary.
    let _serial = span::test_lock();
    prop::forall("span ring wraparound cursors", 3, |rng| {
        span::clear();
        span::enable();
        let before = span::cursor();
        let flood = span::RING_CAP + prop::usize_in(rng, 1..=1500);
        for step in 0..flood {
            let _sp = netbn::span!("prop.flood", 7, step as u32);
        }
        span::disable();
        let (got, cur) = span::since(before, Some(7));
        // Bounded: the oldest overflowed records are gone, the newest
        // survive, and seq numbers stay strictly increasing up to the
        // returned cursor.
        if got.is_empty() || got.len() > span::RING_CAP {
            return Err(format!("{} records survived a flood of {flood}", got.len()));
        }
        for w in got.windows(2) {
            if w[1].seq <= w[0].seq {
                return Err(format!("seq not increasing: {} then {}", w[0].seq, w[1].seq));
            }
        }
        let last = got.last().expect("non-empty").seq;
        if last + 1 != cur {
            return Err(format!("cursor {cur} does not follow last seq {last}"));
        }
        // A wrapped batch still round-trips the wire codec bit-exactly,
        // and re-snapshotting from the cursor ships nothing twice.
        let back = span::decode(&span::encode(&got)).map_err(|e| format!("decode: {e}"))?;
        if back != got {
            return Err("wire codec changed a wrapped batch".to_string());
        }
        if !span::since(cur, Some(7)).0.is_empty() {
            return Err("cursor re-shipped records".to_string());
        }
        span::clear();
        Ok(())
    });
}

#[test]
fn error_feedback_conserves_gradient_mass_exactly() {
    // The error-feedback invariant: shipped + residual == Σ gradients,
    // per coordinate, at every step (this is what makes the compression
    // unbiased over time despite arbitrary per-step dropping).
    use netbn::compress::ErrorFeedback;
    prop::forall("error feedback conservation", 10, |rng| {
        let n = 64;
        let kind = CodecKind::TopK { k_fraction: 0.1 };
        let mut ef = ErrorFeedback::new(kind, n);
        let mut shipped = vec![0.0f64; n];
        let mut fed = vec![0.0f64; n];
        for step in 0..100 {
            let grad = prop::vec_f32(rng, n..=n, 1.0);
            for (f, g) in fed.iter_mut().zip(&grad) {
                *f += *g as f64;
            }
            let enc = ef.compress(&grad, step).map_err(|e| e.to_string())?;
            let dec = codecs::decode(kind, &enc, step).map_err(|e| e.to_string())?;
            for (s, d) in shipped.iter_mut().zip(&dec) {
                *s += *d as f64;
            }
        }
        // Conservation: |fed - shipped| per coordinate is exactly the
        // current residual (up to f32 accumulation noise).
        let deficit: f64 =
            fed.iter().zip(&shipped).map(|(f, s)| (f - s).powi(2)).sum::<f64>().sqrt();
        let residual = ef.residual_norm();
        if (deficit - residual).abs() > 1e-2 * residual.max(1.0) {
            return Err(format!("deficit {deficit} vs residual norm {residual}"));
        }
        Ok(())
    });
}
