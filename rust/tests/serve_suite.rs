//! Integration suite for `netbn serve`: real sockets against a real
//! [`Daemon`] — submission round-trips that match direct registry runs
//! byte-for-byte, admission control at capacity (429 + Retry-After),
//! cancellation semantics, burst throughput beyond the worker count,
//! telemetry polling, and store-backed restart with tuner warm starts.
//!
//! The daemon under test uses its own stop flag (`Daemon::stop`), never
//! process signals — raising SIGTERM here would poison every other test
//! in the binary.

use netbn::serve::http;
use netbn::serve::{Daemon, ServeConfig};
use netbn::util::json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A fresh, empty store directory per test.
fn tmp_store(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "netbn_serve_suite_{tag}_{}_{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn daemon(workers: usize, queue_capacity: usize, store_dir: PathBuf) -> Daemon {
    Daemon::start(&ServeConfig { port: 0, workers, queue_capacity, store_dir }).unwrap()
}

/// POST a submission, asserting 202, returning the allocated id.
fn submit(addr: &str, body: &str) -> u64 {
    let (status, resp) = http::request(addr, "POST", "/jobs", Some(body)).unwrap();
    assert_eq!(status, 202, "{resp}");
    let fields = json::object_fields(&resp).unwrap();
    json::parse_u64(json::require(&fields, "id").unwrap()).unwrap()
}

/// Poll `GET /jobs/<id>` until the job reaches a terminal state.
fn wait_terminal(addr: &str, id: u64, deadline_s: f64) -> String {
    let t0 = Instant::now();
    loop {
        let (status, body) = http::request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        assert_eq!(status, 200, "{body}");
        let fields = json::object_fields(&body).unwrap();
        let state = json::parse_string(json::require(&fields, "state").unwrap()).unwrap();
        if ["done", "failed", "cancelled"].contains(&state.as_str()) {
            return state;
        }
        assert!(
            t0.elapsed().as_secs_f64() < deadline_s,
            "job {id} stuck in state {state:?} after {deadline_s}s"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Blank out the run-specific wall clock so two Outcome JSON strings
/// from the same experiment point compare byte-for-byte.
fn normalize_wall(json: &str) -> String {
    let key = "\"wall_s\":";
    let start = json.find(key).expect("outcome JSON carries wall_s") + key.len();
    let end = start + json[start..].find(',').expect("fields follow wall_s");
    format!("{}0{}", &json[..start], &json[end..])
}

#[test]
fn submitted_outcome_matches_a_direct_registry_run_byte_for_byte() {
    let d = daemon(1, 8, tmp_store("roundtrip"));
    let addr = d.addr().to_string();
    let id = submit(&addr, r#"{"scenario":"simulate","params":{"workers":"8"},"priority":7}"#);
    assert_eq!(wait_terminal(&addr, id, 30.0), "done");

    let (status, served) =
        http::request(&addr, "GET", &format!("/jobs/{id}/outcome"), None).unwrap();
    assert_eq!(status, 200, "{served}");
    let direct = netbn::engine::ScenarioRegistry::builtin()
        .get("simulate")
        .unwrap()
        .run(&[("workers".to_string(), "8".to_string())])
        .unwrap()
        .to_json();
    assert_eq!(
        normalize_wall(&served),
        normalize_wall(&direct),
        "the service must be a transparent wrapper over the registry"
    );

    // The outcome route on a never-run job is a 409, not an empty 200.
    let id2 = submit(&addr, r#"{"scenario":"fig1"}"#);
    let _ = wait_terminal(&addr, id2, 30.0);
    let (status, _) = http::request(&addr, "GET", "/jobs/99/outcome", None).unwrap();
    assert_eq!(status, 404);
}

#[test]
fn burst_of_four_times_the_worker_count_completes_without_loss() {
    // ISSUE acceptance: >= 2W concurrent submissions with no deadlock
    // and no lost jobs. W = 2, burst = 8.
    let d = daemon(2, 16, tmp_store("burst"));
    let addr = d.addr().to_string();
    let ids: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    submit(
                        &addr,
                        &format!(r#"{{"scenario":"simulate","priority":{}}}"#, i % 10),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Unique ids: nothing was lost or double-allocated under concurrency.
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 8, "duplicate job ids in {ids:?}");
    for id in &ids {
        assert_eq!(wait_terminal(&addr, *id, 60.0), "done", "job {id}");
    }
    let (status, body) = http::request(&addr, "GET", "/jobs", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(body.matches("\"state\":\"done\"").count(), 8, "{body}");
}

#[test]
fn full_queue_answers_429_with_retry_after_and_reopens_after_cancel() {
    // No workers: the queue never drains, so capacity is deterministic.
    let d = daemon(0, 2, tmp_store("capacity"));
    let addr = d.addr().to_string();
    let first = submit(&addr, r#"{"scenario":"simulate"}"#);
    submit(&addr, r#"{"scenario":"simulate"}"#);

    // Third submission: refused at admission, with a Retry-After header
    // (read raw off the socket — the test client only surfaces bodies).
    let body = r#"{"scenario":"simulate"}"#;
    let raw = {
        use std::io::{Read as _, Write as _};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /jobs HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };
    assert!(raw.starts_with("HTTP/1.1 429"), "{raw}");
    assert!(raw.contains("Retry-After:"), "{raw}");
    assert!(raw.contains("queue full"), "{raw}");

    // Cancelling a queued job frees a slot: admission reopens.
    let (status, _) = http::request(&addr, "DELETE", &format!("/jobs/{first}"), None).unwrap();
    assert_eq!(status, 200);
    submit(&addr, r#"{"scenario":"simulate"}"#);
}

#[test]
fn cancel_hits_queued_jobs_only() {
    let d = daemon(0, 4, tmp_store("cancel"));
    let addr = d.addr().to_string();
    let id = submit(&addr, r#"{"scenario":"fig1"}"#);
    let (status, body) = http::request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"state\":\"cancelled\""), "{body}");
    assert_eq!(wait_terminal(&addr, id, 1.0), "cancelled");
    // Terminal jobs are not cancellable twice; unknown ids are 404.
    let (status, _) = http::request(&addr, "DELETE", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 409);
    let (status, _) = http::request(&addr, "DELETE", "/jobs/424242", None).unwrap();
    assert_eq!(status, 404);
}

#[test]
fn telemetry_long_poll_pages_without_duplicates_and_closes() {
    let d = daemon(1, 4, tmp_store("telemetry"));
    let addr = d.addr().to_string();
    let id = submit(&addr, r#"{"scenario":"simulate"}"#);
    assert_eq!(wait_terminal(&addr, id, 30.0), "done");
    // First page: the completed job's feed carries at least the final
    // heartbeat (step = u64::MAX) and reports done.
    let (status, body) = http::request(
        &addr,
        "GET",
        &format!("/jobs/{id}/feedback?since=0&timeout=2"),
        None,
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    let fields = json::object_fields(&body).unwrap();
    assert!(json::parse_bool(json::require(&fields, "done").unwrap()).unwrap(), "{body}");
    assert!(body.contains(&format!("\"step\":{}", u64::MAX)), "{body}");
    let next = json::parse_u64(json::require(&fields, "next").unwrap()).unwrap();
    assert!(next >= 1, "{body}");
    // Second page from the cursor: no replayed samples.
    let (_, page2) = http::request(
        &addr,
        "GET",
        &format!("/jobs/{id}/feedback?since={next}&timeout=0"),
        None,
    )
    .unwrap();
    assert!(!page2.contains("\"step\":"), "cursor must not replay: {page2}");
    assert!(page2.contains("\"done\":true"), "{page2}");
}

#[test]
fn restart_resumes_timeseries_seqs_without_loss_or_duplication() {
    use netbn::obs::TsPoint;
    let store = tmp_store("ts_resume");

    // Life A: two deterministic samples into the persisted log (the
    // background sampler's cadence is too slow for a test, so force
    // them; a set gauge guarantees at least one point per sample).
    let a = daemon(0, 2, store.clone());
    netbn::obs::metrics::global().gauge("serve_suite_ts_resume", &[]).set(1.0);
    assert!(a.state().sample_now() > 0, "a set gauge must sample to at least one point");
    netbn::obs::metrics::global().gauge("serve_suite_ts_resume", &[]).set(2.0);
    a.state().sample_now();
    drop(a); // graceful stop: drain + flush

    // Life B on the same store must resume allocating seqs after the
    // persisted high-water mark, not restart from 0 (duplicates) and
    // not leap past it (holes).
    let b = daemon(0, 2, store.clone());
    netbn::obs::metrics::global().gauge("serve_suite_ts_resume", &[]).set(3.0);
    assert!(b.state().sample_now() > 0);
    drop(b);

    let text = std::fs::read_to_string(store.join("timeseries.jsonl")).unwrap();
    let mut seqs: Vec<u64> = text
        .lines()
        .map(|l| TsPoint::from_json_line(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e:#}")).seq)
        .collect();
    assert!(seqs.len() >= 3, "three forced samples persisted {} points", seqs.len());
    // Sorted (concurrent background samples may interleave file order),
    // the persisted seqs are exactly 0..n — every cursor appears once.
    seqs.sort_unstable();
    for (i, seq) in seqs.iter().enumerate() {
        assert_eq!(*seq, i as u64, "seq hole or duplicate across restart: {seqs:?}");
    }
}

#[test]
fn restart_preserves_history_and_warm_starts_resubmissions() {
    let store = tmp_store("restart");

    // Life A: run an autotuning emulate job to completion, which
    // persists a tuner checkpoint for the scenario in the store.
    let a = daemon(1, 4, store.clone());
    let addr_a = a.addr().to_string();
    let body = r#"{"scenario":"emulate","params":{"autotune":"on","servers":"2","steps":"2","payload-scale":"2048"}}"#;
    let (status, resp) = http::request(&addr_a, "POST", "/jobs", Some(body)).unwrap();
    assert_eq!(status, 202, "{resp}");
    assert!(resp.contains("\"warm_start\":false"), "no checkpoint yet: {resp}");
    let id = json::parse_u64(
        json::require(&json::object_fields(&resp).unwrap(), "id").unwrap(),
    )
    .unwrap();
    assert_eq!(wait_terminal(&addr_a, id, 120.0), "done");
    drop(a); // graceful stop: drain + flush

    // Life B on the same store: history intact, ids advance, and an
    // unpinned resubmission is flagged for a warm start from the
    // persisted checkpoint.
    let b = daemon(0, 4, store);
    let addr_b = b.addr().to_string();
    let (status, record) =
        http::request(&addr_b, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(status, 200, "{record}");
    assert!(record.contains("\"state\":\"done\""), "{record}");
    assert!(record.contains("\"outcome\":{"), "outcome must survive restart: {record}");
    assert!(record.contains("\"tuned_knobs\":"), "the run tuned knobs: {record}");

    let (status, resp) = http::request(&addr_b, "POST", "/jobs", Some(body)).unwrap();
    assert_eq!(status, 202, "{resp}");
    assert!(resp.contains("\"warm_start\":true"), "checkpoint should warm-start: {resp}");
    let id2 = json::parse_u64(
        json::require(&json::object_fields(&resp).unwrap(), "id").unwrap(),
    )
    .unwrap();
    assert!(id2 > id, "ids must keep advancing across restarts: {id} then {id2}");

    // Reloaded history has no live feed: feedback is immediately done.
    let (status, fb) =
        http::request(&addr_b, "GET", &format!("/jobs/{id}/feedback"), None).unwrap();
    assert_eq!(status, 200, "{fb}");
    assert!(fb.contains("\"done\":true"), "{fb}");
}
