//! Cross-fabric transport conformance: every collective × {inproc, tcp}
//! × {single, striped} produces **bit-identical** reduced tensors, and
//! the striped transport beats the single-stream one wall-clock on a
//! shaped 10 Gbps emulation.
//!
//! The transport layer must be invisible to the math: striping changes
//! *how* bytes traverse the fabric, never *which* bytes. Since every
//! collective performs its additions in a deterministic order, the f32
//! bit patterns must agree across all fabric × transport combinations.

use netbn::collectives::hierarchical::hier_allreduce;
use netbn::collectives::{ps::ps_allreduce, ring::ring_allreduce, tree::tree_allreduce};
use netbn::net::buf::BufPool;
use netbn::net::inproc::InProcFabric;
use netbn::net::shaper::Shaper;
use netbn::net::striped::{StripeConfig, StripedTransport};
use netbn::net::transport::{SingleStream, Transport, TransportFabric};
use netbn::net::{Endpoint, Fabric};
use netbn::topology::{Cluster, Ring, Topology, WorkerId};
use netbn::util::prop::fnv1a;
use netbn::util::Rng;
use std::io::IoSlice;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

const WORKERS: usize = 3;
/// Uneven length: exercises ragged ring chunks and partial stripe chunks.
const LEN: usize = 1003;

/// A stripe config small enough that the test tensors genuinely stripe
/// and (with a 1-chunk window) genuinely wait on credits.
fn test_stripe_cfg() -> StripeConfig {
    StripeConfig { streams: 4, chunk_bytes: 512, credit_window: 1 }
}

fn inputs() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xc0f0);
    (0..WORKERS)
        .map(|_| {
            let mut v = vec![0.0f32; LEN];
            rng.fill_f32(&mut v, 2.0);
            v
        })
        .collect()
}

#[derive(Clone, Copy, Debug)]
enum FabricKind {
    Inproc,
    Tcp,
}

fn build_fabric(kind: FabricKind, transport: &dyn Transport) -> Box<dyn Fabric> {
    match kind {
        FabricKind::Inproc => {
            Box::new(TransportFabric::inproc(WORKERS, transport, None).unwrap())
        }
        FabricKind::Tcp => Box::new(TransportFabric::tcp(WORKERS, transport, None).unwrap()),
    }
}

type CollectiveFn = fn(&dyn Endpoint, &Ring, u32, u32, &mut [f32]) -> netbn::Result<()>;

/// Adapter so the hierarchical collective fits the flat-ring harness:
/// groups of 2 over the whole world (the `Ring` argument only supplies
/// the signature; membership comes from the cluster).
fn hier_groups_of_two(
    ep: &dyn Endpoint,
    _ring: &Ring,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> netbn::Result<()> {
    hier_allreduce(ep, &Cluster::new(ep.world(), 2), step, bucket, data)
}

/// Run one collective across the fabric and return every worker's result.
fn run_collective(fabric: &dyn Fabric, f: CollectiveFn, fused: bool) -> Vec<Vec<f32>> {
    let ring = Topology::new(WORKERS, 1).flat_ring();
    let mut handles = Vec::new();
    for (ep, mut data) in fabric.endpoints().into_iter().zip(inputs()) {
        let ring = ring.clone();
        handles.push(thread::spawn(move || {
            if fused {
                // The fused path: the fusion buffer splits a step's
                // gradients into buckets, each all-reduced under its own
                // bucket id. Two buckets stand in for that here.
                let mid = data.len() / 2;
                let (a, b) = data.split_at_mut(mid);
                f(ep.as_ref(), &ring, 0, 0, a).unwrap();
                f(ep.as_ref(), &ring, 0, 1, b).unwrap();
            } else {
                f(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
            }
            data
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn collectives_bit_identical_across_fabrics_and_transports() {
    let collectives: [(&str, CollectiveFn, bool); 5] = [
        ("ring", ring_allreduce, false),
        ("tree", tree_allreduce, false),
        ("ps", ps_allreduce, false),
        ("hier", hier_groups_of_two, false),
        ("fused-ring", ring_allreduce, true),
    ];
    for (name, f, fused) in collectives {
        let mut reference: Option<Vec<u32>> = None;
        for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
            let single = SingleStream;
            let striped = StripedTransport::new(test_stripe_cfg());
            let transports: [(&str, &dyn Transport); 2] =
                [("single", &single), ("striped:4", &striped)];
            for (tname, transport) in transports {
                let fabric = build_fabric(fabric_kind, transport);
                let results = run_collective(fabric.as_ref(), f, fused);
                // All ranks agree within one run...
                let first = bits(&results[0]);
                for (w, r) in results.iter().enumerate() {
                    assert_eq!(
                        bits(r),
                        first,
                        "{name} over {fabric_kind:?}/{tname}: rank {w} disagrees"
                    );
                }
                // ...and every fabric × transport combination agrees with
                // the first one, bit for bit.
                match &reference {
                    None => reference = Some(first),
                    Some(want) => assert_eq!(
                        &first, want,
                        "{name} over {fabric_kind:?}/{tname}: differs from reference"
                    ),
                }
            }
        }
    }
}

#[test]
fn empty_and_tiny_payloads_conform() {
    // Barrier-sized traffic must also be transport-invariant.
    for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
        let striped = StripedTransport::new(test_stripe_cfg());
        let fabric = build_fabric(fabric_kind, &striped);
        let eps = fabric.endpoints();
        let mut handles = Vec::new();
        for ep in eps {
            handles.push(thread::spawn(move || {
                netbn::collectives::barrier(ep.as_ref(), 0).unwrap();
                netbn::collectives::barrier(ep.as_ref(), 1).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// The satellite's wall-clock claim: on a shaped 10 Gbps emulation whose
/// software pipeline caps each stream at a quarter of the NIC,
/// striped:4 moves a bulk payload materially faster than single-stream.
#[test]
fn striped_beats_single_stream_on_shaped_10gbps() {
    // 10 Gbps scaled down 1024x => ~1.22 MB/s NIC; per-stream software
    // ceiling at a quarter of that, the regime the paper measured.
    let scale = 1024.0;
    let nic_rate = netbn::gbps_to_bytes_per_sec(10.0) / scale;
    let per_stream = nic_rate / 4.0;
    let payload = vec![42u8; 400_000];

    let timed = |streams: usize| -> f64 {
        let cfg = StripeConfig { streams, chunk_bytes: 16 << 10, credit_window: 4 };
        let transport = StripedTransport::with_stream_ceiling(cfg, per_stream);
        let shaper = Arc::new(Shaper::new(Topology::new(2, 1), nic_rate, 0.0));
        let fabric = TransportFabric::inproc(2, &transport, Some(shaper)).unwrap();
        let eps = fabric.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let h = thread::spawn(move || b.recv(WorkerId(0), 1).unwrap());
        let t0 = Instant::now();
        a.send(WorkerId(1), 1, &payload).unwrap();
        let got = h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(got.len(), payload.len());
        dt
    };

    let single_s = timed(1);
    let striped_s = timed(4);
    assert!(
        striped_s < single_s * 0.7,
        "striped:4 {striped_s:.2}s should beat single-stream {single_s:.2}s by >= 30%"
    );
}

/// The buffer-aware API leg: a gathered `send_vectored` received with
/// `recv_into` must deliver byte-identical payloads (same FNV-1a
/// checksum) across every fabric × transport combination, on both the
/// fused (small) and striped (large) paths.
#[test]
fn vectored_send_recv_into_conform_across_matrix() {
    // Large enough to stripe under `test_stripe_cfg`, plus a payload that
    // stays on the fused path.
    let large: Vec<u8> =
        (0..100_000u32).map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8).collect();
    let small: Vec<u8> = (0..100u8).collect();
    let want_large = fnv1a(&large);
    let want_small = fnv1a(&small);

    for fabric_kind in [FabricKind::Inproc, FabricKind::Tcp] {
        let single = SingleStream;
        let striped = StripedTransport::new(test_stripe_cfg());
        let transports: [(&str, &dyn Transport); 2] =
            [("single", &single), ("striped:4", &striped)];
        for (tname, transport) in transports {
            let fabric = build_fabric(fabric_kind, transport);
            let eps = fabric.endpoints();
            let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
            let (ln, sn) = (large.len(), small.len());
            let h = thread::spawn(move || {
                // Extra headroom: recv_into reports the true length.
                let mut big = vec![0u8; ln + 7];
                let got_l = b.recv_into(WorkerId(0), 9, &mut big).unwrap();
                big.truncate(got_l);
                let mut tiny = vec![0u8; sn];
                let got_s = b.recv_into(WorkerId(0), 10, &mut tiny).unwrap();
                tiny.truncate(got_s);
                (big, tiny)
            });
            // Three uneven slices exercise the gather/scatter path.
            let (x, rest) = large.split_at(11);
            let (y, z) = rest.split_at(60_000);
            a.send_vectored(
                WorkerId(1),
                9,
                &[IoSlice::new(x), IoSlice::new(y), IoSlice::new(z)],
            )
            .unwrap();
            a.send_vectored(WorkerId(1), 10, &[IoSlice::new(&small)]).unwrap();
            let (big, tiny) = h.join().unwrap();
            assert_eq!(big.len(), large.len(), "{fabric_kind:?}/{tname}: large length");
            assert_eq!(fnv1a(&big), want_large, "{fabric_kind:?}/{tname}: large checksum");
            assert_eq!(tiny.len(), small.len(), "{fabric_kind:?}/{tname}: small length");
            assert_eq!(fnv1a(&tiny), want_small, "{fabric_kind:?}/{tname}: small checksum");
        }
    }
}

/// One striped send + `recv_into` round trip over endpoints whose lanes
/// and transport share `pool`.
fn pooled_exchange(eps: &[Arc<dyn Endpoint>], payload: &[u8], tag: u64) {
    let b = Arc::clone(&eps[1]);
    let n = payload.len();
    let h = thread::spawn(move || {
        let mut dst = vec![0u8; n];
        let got = b.recv_into(WorkerId(0), tag, &mut dst).unwrap();
        assert_eq!(got, n);
        dst
    });
    eps[0].send(WorkerId(1), tag, payload).unwrap();
    let got = h.join().unwrap();
    assert_eq!(fnv1a(&got), fnv1a(payload));
}

/// The tentpole's zero-allocation claim, enforced by counting: after a
/// few warmup rounds populate the size classes, the striped hot path —
/// stripe buffers, lane frames, credits, reassembly — performs **zero**
/// fresh allocations from the shared pool, detaches nothing, and every
/// [`PooledBuf`] returns to the pool (outstanding drains to 0).
#[test]
fn striped_hot_path_allocates_zero_at_steady_state() {
    let pool = BufPool::new();
    let transport = StripedTransport::with_pool(test_stripe_cfg(), pool.clone());
    let fabric = TransportFabric::new(&transport, || {
        Ok(Box::new(InProcFabric::with_shaper_and_pool(2, None, pool.clone())) as Box<dyn Fabric>)
    })
    .unwrap();
    let eps = fabric.endpoints();
    // 40 KB stripes into 4 × 10 KB, dozens of 512 B chunks per lane.
    let payload: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();

    // Deterministic pre-warm: pin the high-water mark of every size class
    // the hot path touches (stripe buffers, chunk frames, the header
    // frame) above any concurrency the exchanges can reach, so the
    // steady-state assertion cannot be scheduling-sensitive.
    let prewarm: Vec<_> =
        (0..8).flat_map(|_| [pool.get(10_000), pool.get(512), pool.get(8)]).collect();
    drop(prewarm);

    for tag in 0..4 {
        pooled_exchange(&eps, &payload, tag);
    }
    let warm = pool.stats();
    assert_eq!(warm.outstanding, 0, "warmup must drain: {warm:?}");

    for tag in 0..32 {
        pooled_exchange(&eps, &payload, 100 + tag);
    }
    let s = pool.stats();
    assert_eq!(
        s.fresh_allocs, warm.fresh_allocs,
        "striped hot path must not allocate at steady state: {s:?} vs warm {warm:?}"
    );
    assert_eq!(s.detached, warm.detached, "pooled hot path must not detach buffers: {s:?}");
    assert_eq!(s.outstanding, 0, "every PooledBuf must return to the pool: {s:?}");
    assert!(s.reuses > warm.reuses, "steady state must be served by reuse: {s:?}");
}
