//! Autotuner determinism and safety suite — the control plane's two
//! hard promises, checked end to end:
//!
//! 1. **Determinism**: identical seeds (and identical feedback) yield
//!    identical knob trajectories — directly on the controller, and
//!    through the engine where serial and `--parallel` sweeps must emit
//!    identical per-point results;
//! 2. **Safety**: an autotuned `netbn launch` produces FNV checksums
//!    bit-identical to the static-config run — knob broadcasts retune
//!    how bytes move, never what they sum to.
//!
//! Plus the convergence-quality floor the scenarios gate on: coordinate
//! descent over the analytic oracle lands within 10% of the exhaustive
//! sweep at every paper rate.

use netbn::config::{CollectiveKind, OverlapMode, TransportKind};
use netbn::engine::{ScenarioRegistry, SweepBuilder};
use netbn::models::ModelId;
use netbn::trainer::launch::{launch, LaunchConfig, SpawnMode, WorkerParams};
use netbn::tune::{
    drive_until_exploit, AutoTuner, KnobPoint, KnobSpace, OracleEnv, StepFeedback, TunerConfig,
};
use netbn::util::Rng;

#[test]
fn same_seed_yields_identical_knob_trajectories() {
    let env = OracleEnv::new(ModelId::ResNet50, 8, 8);
    let run = |seed: u64| {
        let cfg = TunerConfig { seed, ..TunerConfig::default() };
        let mut tuner =
            AutoTuner::new(KnobSpace::default(), cfg, &KnobPoint::default_static()).unwrap();
        let mut rng = Rng::new(seed ^ 0xfeed);
        assert!(drive_until_exploit(&mut tuner, &env, 10.0, 0.01, &mut rng, 400).is_some());
        tuner.trajectory().to_vec()
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "same seed, same feedback, different trajectory");
    assert!(a.len() >= 2, "the probe must have moved the applied point");
}

#[test]
fn convergence_within_ten_percent_at_every_paper_rate() {
    // The scenario acceptance floor, swept: the controller's chosen point
    // vs the exhaustive sweep over the same 240-point grid.
    let env = OracleEnv::new(ModelId::ResNet50, 8, 8);
    let space = KnobSpace::default();
    for (i, bw) in [1.0, 10.0, 25.0, 100.0].into_iter().enumerate() {
        let cfg = TunerConfig { seed: 0x1009 + i as u64, ..TunerConfig::default() };
        let mut tuner =
            AutoTuner::new(space.clone(), cfg, &KnobPoint::default_static()).unwrap();
        let mut rng = Rng::new(0xbead ^ i as u64);
        assert!(
            drive_until_exploit(&mut tuner, &env, bw, 0.01, &mut rng, 400).is_some(),
            "{bw} Gbps: no exploit"
        );
        let tuned = env.step_time_s(bw, &tuner.chosen());
        let (_, best) = env.best(bw, &space);
        assert!(
            tuned <= best * 1.10,
            "{bw} Gbps: tuned {tuned} vs sweep best {best} ({:.1}% above)",
            (tuned / best - 1.0) * 100.0
        );
    }
}

#[test]
fn serial_and_parallel_sweeps_emit_identical_tuning_results() {
    // The engine face of determinism: `seed` is a declared parameter, so
    // the sweep injects an index-derived per-point seed and thread count
    // cannot change any outcome.
    let registry = ScenarioRegistry::builtin();
    let scenario = registry.get("autotune_convergence").unwrap();
    let build = || {
        SweepBuilder::new(scenario)
            .fix("fnv-check", "off")
            .fix("max-steps", "300")
            .axis_csv("bandwidth", "5,25,100")
    };
    let serial = build().run(1);
    let parallel = build().run(3);
    assert_eq!(serial.len(), 3);
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.params, p.params);
        let (so, po) = (s.outcome.as_ref().unwrap(), p.outcome.as_ref().unwrap());
        for key in [
            "tuned_step_s",
            "ratio_to_optimum",
            "knob_changes",
            "steps_to_converge",
            "final_chunk_kb",
        ] {
            assert_eq!(
                so.metric_value(key),
                po.metric_value(key),
                "{key} diverged between serial and parallel"
            );
        }
    }
}

#[test]
fn autotuned_launch_checksums_match_static_run() {
    // The e2e safety gate, independent of the scenario wrapper: chunk
    // retuning over real loopback sockets with knob broadcasts, against
    // the static run with the same seeds.
    let params = WorkerParams {
        world: 3,
        steps: 10,
        elems: 50_000,
        transport: TransportKind::Striped { streams: 2 },
        collective: CollectiveKind::Hierarchical { group_size: 2 },
        overlap: OverlapMode::Off,
        bucket_mb: 0.0,
        layers: 1,
        compute_us: 0,
        autotune: false,
        chunk_kbs: Vec::new(),
        gate_gbps: 0.0,
        drop_at_step: 0,
        drop_gbps: 0.0,
        seed: 0x7e57_5eed,
        obs: false,
        trace_out: None,
    };
    let static_run = launch(&LaunchConfig {
        params: params.clone(),
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })
    .unwrap();
    let tuned_run = launch(&LaunchConfig {
        params: WorkerParams { autotune: true, chunk_kbs: vec![2, 8, 48], ..params },
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })
    .unwrap();
    assert!(static_run.identical && tuned_run.identical);
    assert_eq!(
        static_run.checksums, tuned_run.checksums,
        "knob broadcasts changed the arithmetic"
    );
    assert!(
        tuned_run.knob_trajectory.len() >= 2,
        "10 steps must probe at least one non-initial chunk: {:?}",
        tuned_run.knob_trajectory
    );
}

#[test]
fn launch_feedback_trace_replays_into_the_tuner_types() {
    // Capture → replay: the trace a launch writes feeds the same types
    // the online loop uses (the `netbn tune --from-trace` path).
    let path = std::env::temp_dir().join("netbn_tune_suite_feedback.jsonl");
    let mut cfg = LaunchConfig {
        params: WorkerParams {
            world: 2,
            steps: 4,
            elems: 20_000,
            transport: TransportKind::Tcp,
            collective: CollectiveKind::Ring,
            overlap: OverlapMode::Off,
            bucket_mb: 0.0,
            layers: 1,
            compute_us: 0,
            autotune: false,
            chunk_kbs: Vec::new(),
            gate_gbps: 0.0,
            drop_at_step: 0,
            drop_gbps: 0.0,
            seed: 0xcafe,
            obs: false,
            trace_out: None,
        },
        spawn: SpawnMode::Thread,
        feedback_out: Some(path.clone()),
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    };
    cfg.params.steps = 4;
    let r = launch(&cfg).unwrap();
    assert!(r.passed());
    let records = netbn::measure::trace::load_step_feedback(&path).unwrap();
    assert_eq!(records.len(), 4);
    let mut ring = netbn::tune::FeedbackRing::new(8);
    for rec in &records {
        ring.push(StepFeedback::from_record(rec));
    }
    assert_eq!(ring.len(), 4);
    assert!(ring.mean_wall(4) > 0.0);
}
