//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the API subset `netbn` uses:
//!
//! * [`Error`] — a message + cause chain (`Display` prints the top message,
//!   `{:#}` prints the whole chain, `Debug` prints an anyhow-style
//!   "Caused by" listing);
//! * [`Result<T>`] with the `E = Error` default;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (literal, formatted
//!   and expression forms);
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//!   for any `Result` whose error converts into [`Error`] — which covers
//!   both `std` errors and `Error` itself — and for `Option<T>` (a `None`
//!   becomes an error carrying the context message, like real anyhow's
//!   `impl Context for Option`).
//!
//! Anything not listed here is intentionally absent; add it only when a
//! caller needs it.

use std::fmt;

/// Error: a human-readable message plus an optional cause chain.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error` — that is what allows the blanket
/// `From<E: std::error::Error>` conversion to coexist with `From<Error>`
/// (the identity conversion used by `?`).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn wrap<M: fmt::Display>(self, msg: M) -> Error {
        Error { msg: msg.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }

    #[doc(hidden)]
    pub fn from_any<E: Into<Error>>(e: E) -> Error {
        e.into()
    }

    fn from_msgs(msgs: Vec<String>) -> Error {
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap_or_default(), source: None };
        for m in it {
            err = Error { msg: m, source: Some(Box::new(err)) };
        }
        err
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error::from_msgs(msgs)
    }
}

/// `anyhow::Result`: plain `Result` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to any
/// `Result` whose error converts into [`Error`].
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().wrap(f()))
    }
}

/// `Option` support, mirroring real anyhow: `None.context("msg")` yields
/// `Err(Error::msg("msg"))` — no more `ok_or_else(|| anyhow!(..))`
/// workarounds. The phantom error type is [`std::convert::Infallible`],
/// exactly as upstream declares it.
impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message literal, a format string, or an
/// expression convertible into [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::from_any($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($msg:literal $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($msg))
    };
    ($err:expr $(,)?) => {
        return ::std::result::Result::Err($crate::anyhow!($err))
    };
    ($fmt:literal, $($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: {}",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $msg:literal $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($msg));
        }
    };
    ($cond:expr, $err:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($err));
        }
    };
    ($cond:expr, $fmt:literal, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($fmt, $($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::msg("inner").wrap("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_wraps_both_std_and_anyhow_errors() {
        let e = fails_io().context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let base: Result<()> = Err(anyhow!("base"));
        let e = base.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: base");
    }

    #[test]
    fn context_on_option() {
        let some: Option<i32> = Some(7);
        assert_eq!(some.context("missing").unwrap(), 7);
        let none: Option<i32> = None;
        assert_eq!(none.context("missing value").unwrap_err().to_string(), "missing value");
        let none: Option<i32> = None;
        let e = none.with_context(|| format!("no entry for {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "no entry for k");
        // The lazy form must not evaluate on Some.
        let some: Option<i32> = Some(1);
        let r = some.with_context(|| -> String { panic!("must not run") });
        assert_eq!(r.unwrap(), 1);
    }

    #[test]
    fn macro_forms() {
        let x = 3;
        assert_eq!(anyhow!("literal").to_string(), "literal");
        assert_eq!(anyhow!("x = {x}").to_string(), "x = 3");
        assert_eq!(anyhow!("x = {}", x + 1).to_string(), "x = 4");
        assert_eq!(anyhow!(Error::msg("passthrough")).to_string(), "passthrough");

        fn bails(n: i32) -> Result<()> {
            ensure!(n < 10, "too big: {n}");
            if n < 0 {
                bail!("negative");
            }
            ensure!(n != 5);
            Ok(())
        }
        assert!(bails(3).is_ok());
        assert_eq!(bails(12).unwrap_err().to_string(), "too big: 12");
        assert_eq!(bails(-1).unwrap_err().to_string(), "negative");
        assert!(bails(5).unwrap_err().to_string().contains("n != 5"));
    }
}
