//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real crate wraps a PJRT CPU plugin and executes the AOT HLO
//! artifacts produced by `python/compile/aot.py`. This build environment
//! has neither crates.io nor a PJRT plugin, so this stub preserves the
//! exact API surface `netbn::runtime` compiles against while failing at
//! the first point a real backend would be required: parsing an HLO
//! module. Client construction succeeds (so the device service starts and
//! missing-artifact errors stay precise), and every artifact-dependent
//! call returns [`Error::unavailable`].
//!
//! Swap this path dependency for the real `xla` crate to run the e2e
//! training path; nothing in `netbn` changes.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: carries a message; implements `std::error::Error` so the
/// caller's `anyhow` conversions work unchanged.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable in this offline build \
             (vendor/xla is a stub; substitute the real xla crate to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types PJRT buffers can carry (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
}

/// Marker for host element types `Literal` can be built from.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// A host-side literal (stub: no storage).
#[derive(Clone, Debug, Default)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (stub: never constructible from text).
#[derive(Clone, Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parse HLO {:?}", path.as_ref())))
    }
}

/// An XLA computation handle.
#[derive(Clone, Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device buffer handle returned by execution.
#[derive(Clone, Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. Construction succeeds so hosts can start their device
/// service and report precise errors (e.g. missing artifacts) before any
/// backend work is attempted.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_backend_calls_fail() {
        let c = PjRtClient::cpu().unwrap();
        let proto_err = HloModuleProto::from_text_file("/nope.hlo.txt").unwrap_err();
        assert!(proto_err.to_string().contains("offline"), "{proto_err}");
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(c.compile(&comp).is_err());
    }

    #[test]
    fn literal_construction_is_cheap_and_safe() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
